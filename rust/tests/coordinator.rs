//! Coordinator integration: service batching invariants, pool stream
//! equivalence, registry dispatch, heuristic selection.

use portarng::coordinator::{
    BackendHeuristic, BackendRegistry, DispatchPolicy, PoolConfig, RngService, ServicePool,
};
use portarng::platform::PlatformId;
use portarng::rng::{Engine, PhiloxEngine};
use portarng::testkit;

#[test]
fn prop_batched_service_equals_dedicated_stream() {
    // The fundamental batching invariant: any sequence of requests, any
    // batching thresholds — concatenated replies equal one dedicated
    // Philox stream.
    testkit::forall("service-stream-exact", 12, |g| {
        let seed = g.u64();
        let max_batch = g.usize_in(64, 4096);
        let max_requests = g.usize_in(1, 8);
        let svc = RngService::spawn(PlatformId::A100, seed, max_batch, max_requests);
        let n_req = g.usize_in(1, 12);
        let sizes: Vec<usize> = (0..n_req).map(|_| g.usize_in(1, 700)).collect();
        // Sizes multiples of 4 keep the padded launch == payload so the
        // dedicated stream lines up exactly.
        let sizes: Vec<usize> = sizes.iter().map(|s| s.div_ceil(4) * 4).collect();
        let rxs: Vec<_> = sizes.iter().map(|&n| svc.generate(n, (0.0, 1.0))).collect();
        svc.flush();
        let mut got = Vec::new();
        for rx in rxs {
            got.extend(rx.recv().map_err(|e| e.to_string())?.map_err(|e| e.to_string())?);
        }
        let mut want = vec![0f32; got.len()];
        PhiloxEngine::new(seed).fill_uniform_f32(&mut want);
        if got != want {
            return Err(format!("stream mismatch ({} numbers)", got.len()));
        }
        svc.shutdown().map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_pooled_batched_output_is_bit_identical_to_dedicated_engines() {
    // The pool-wide invariant for shard counts {1, 2, 8} and mixed request
    // sizes: every reply equals a dedicated engine skipped to the
    // request's global offset, and the in-order concatenation equals one
    // contiguous stream — independent of batching thresholds, padding and
    // the size-aware overflow lane.
    testkit::forall("pool-stream-exact", 6, |g| {
        let seed = g.u64();
        let n_req = g.usize_in(3, 14);
        // Mixed sizes: mostly small, occasionally large enough to trip the
        // overflow threshold; deliberately not multiples of 4.
        let sizes: Vec<usize> = (0..n_req)
            .map(|_| {
                if g.bool_with(0.25) {
                    g.usize_in(800, 3000)
                } else {
                    g.usize_in(1, 500)
                }
            })
            .collect();
        let max_batch = g.usize_in(64, 4096);
        let max_requests = g.usize_in(1, 6);
        for shards in [1usize, 2, 8] {
            let mut cfg = PoolConfig::new(PlatformId::A100, seed, shards);
            cfg.max_batch = max_batch;
            cfg.max_requests = max_requests;
            cfg.policy = DispatchPolicy::fixed(800);
            let pool = ServicePool::spawn(cfg);
            let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
            pool.flush();
            let mut offset = 0u64;
            let mut concat = Vec::new();
            for (rx, &n) in rxs.iter().zip(&sizes) {
                let got = rx
                    .recv()
                    .map_err(|e| e.to_string())?
                    .map_err(|e| e.to_string())?;
                let mut want = vec![0f32; n];
                PhiloxEngine::with_offset(seed, offset).fill_uniform_f32(&mut want);
                if got != want {
                    return Err(format!(
                        "shards={shards}: request at offset {offset} (n={n}) diverged"
                    ));
                }
                offset += n as u64;
                concat.extend(got);
            }
            let mut whole = vec![0f32; concat.len()];
            PhiloxEngine::new(seed).fill_uniform_f32(&mut whole);
            if concat != whole {
                return Err(format!("shards={shards}: concatenation != dedicated stream"));
            }
            let stats = pool.shutdown().map_err(|e| e.to_string())?;
            if stats.total().requests != sizes.len() as u64 {
                return Err("request count mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn pool_shutdown_flushes_pending_requests_on_every_shard() {
    let mut cfg = PoolConfig::new(PlatformId::Vega56, 11, 3);
    cfg.max_requests = 1000; // nothing closes a batch before shutdown
    let pool = ServicePool::spawn(cfg);
    let rxs: Vec<_> = (0..9).map(|_| pool.generate(33, (0.0, 1.0))).collect();
    let stats = pool.shutdown().unwrap();
    assert_eq!(stats.total().requests, 9);
    for rx in rxs {
        assert!(rx.recv().unwrap().is_ok());
    }
}

#[test]
fn service_counts_launches_not_requests() {
    let svc = RngService::spawn(PlatformId::Vega56, 1, 1 << 20, 4);
    for _ in 0..8 {
        let _ = svc.generate(100, (0.0, 1.0));
    }
    svc.flush();
    let stats = svc.shutdown().unwrap();
    assert_eq!(stats.requests, 8);
    assert_eq!(stats.launches, 2); // 8 requests / max_requests=4
}

#[test]
fn registry_round_trip_all_platforms() {
    let reg = BackendRegistry::new();
    for p in PlatformId::ALL {
        let backend = reg.native_for(p);
        let mut gen = backend
            .create_generator(portarng::rng::EngineKind::Philox4x32x10, 3)
            .unwrap();
        let mut out = vec![0f32; 64];
        gen.generate_canonical(&portarng::rng::Distribution::uniform(0.0, 1.0), &mut out)
            .unwrap();
        assert!(out.iter().all(|&x| (0.0..1.0).contains(&x)), "{p:?}");
    }
}

#[test]
fn heuristic_crossovers_ordered_by_device_overheads() {
    let a100 = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
    let vega = BackendHeuristic::calibrate(PlatformId::Vega56, PlatformId::XeonGold5220);
    // Both GPUs need enough work to amortise launch+runtime overheads.
    for h in [&a100, &vega] {
        assert!(h.crossover > 1_000, "crossover {}", h.crossover);
        assert!(h.crossover < 100_000_000, "crossover {}", h.crossover);
    }
}

#[test]
fn heuristic_never_worse_than_worst_fixed_choice() {
    use portarng::burner::{run_burner_virtual, BurnerApi, BurnerConfig};
    let h = BackendHeuristic::calibrate(PlatformId::A100, PlatformId::Rome7742);
    for batch in [10usize, 10_000, 1_000_000, 100_000_000] {
        let t = |p: PlatformId| {
            let mut c = BurnerConfig::paper_default(p, BurnerApi::SyclBuffer, batch);
            c.iterations = 3;
            let r = run_burner_virtual(&c).unwrap();
            r.mean_total_ns() - r.breakdown.d2h_ns as f64
        };
        let host = t(PlatformId::Rome7742);
        let device = t(PlatformId::A100);
        let picked = t(h.select(batch));
        assert!(
            picked <= host.max(device) * 1.05,
            "batch {batch}: picked {picked} vs {host}/{device}"
        );
    }
}
