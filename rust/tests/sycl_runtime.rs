//! Property-based integration tests over the mini-SYCL runtime: random
//! command graphs must always produce valid, dependency-respecting
//! virtual timelines (the §3 runtime guarantee).

use portarng::platform::{CommandCost, PlatformId};
use portarng::sycl::{
    AccessMode, Buffer, CommandClass, Dag, Queue, SyclRuntimeProfile,
};
use portarng::testkit;

fn kernel(items: u64) -> CommandCost {
    CommandCost::Kernel { bytes_read: 0, bytes_written: items * 4, items, tpb: 0 }
}

fn random_platform(g: &mut testkit::Gen) -> PlatformId {
    *g.choose(&PlatformId::ALL)
}

fn random_profile(g: &mut testkit::Gen) -> SyclRuntimeProfile {
    *g.choose(&[SyclRuntimeProfile::Dpcpp, SyclRuntimeProfile::HipSycl])
}

#[test]
fn prop_random_buffer_graphs_always_valid() {
    testkit::forall("random-buffer-graphs", 40, |g| {
        let queue = Queue::new(random_platform(g), random_profile(g));
        let n_buffers = g.usize_in(1, 4);
        let buffers: Vec<Buffer<f32>> =
            (0..n_buffers).map(|_| Buffer::new(g.usize_in(16, 4096))).collect();
        let n_cmds = g.usize_in(1, 25);
        for i in 0..n_cmds {
            let buf = buffers[g.usize_in(0, n_buffers - 1)].clone();
            let mode = *g.choose(&[AccessMode::Read, AccessMode::Write, AccessMode::ReadWrite]);
            let items = g.range(1, 1 << 20);
            queue.submit(move |cgh| {
                let acc = cgh.require(&buf, mode);
                cgh.host_task(format!("k{i}"), CommandClass::Other, kernel(items), move |_| {
                    let _ = acc;
                });
            });
        }
        let records = queue.records();
        let dag = Dag::new(&records);
        dag.validate().map_err(|e| format!("invalid DAG: {e}"))?;
        let stats = dag.stats();
        if stats.critical_path_ns > stats.makespan_ns {
            return Err(format!(
                "critical path {} exceeds makespan {}",
                stats.critical_path_ns, stats.makespan_ns
            ));
        }
        if queue.wait() < stats.makespan_ns {
            return Err("wait() ended before the last command".into());
        }
        Ok(())
    });
}

#[test]
fn prop_in_order_queue_never_overlaps() {
    testkit::forall("in-order-no-overlap", 25, |g| {
        let queue = Queue::in_order(random_platform(g), random_profile(g));
        let buffers: Vec<Buffer<f32>> = (0..3).map(|_| Buffer::new(64)).collect();
        for i in 0..g.usize_in(2, 15) {
            let buf = buffers[g.usize_in(0, 2)].clone();
            let items = g.range(1, 1 << 16);
            queue.submit(move |cgh| {
                let acc = cgh.require(&buf, AccessMode::Write);
                cgh.host_task(format!("k{i}"), CommandClass::Other, kernel(items), move |_| {
                    let _ = acc;
                });
            });
        }
        let records = queue.records();
        if Dag::new(&records).has_overlap() {
            return Err("in-order queue produced overlapping commands".into());
        }
        Ok(())
    });
}

#[test]
fn prop_same_buffer_chain_is_fully_ordered() {
    testkit::forall("same-buffer-chain", 25, |g| {
        let queue = Queue::new(random_platform(g), random_profile(g));
        let buf = Buffer::<f32>::new(256);
        let n = g.usize_in(2, 12);
        let mut last_end = 0u64;
        for i in 0..n {
            let b = buf.clone();
            let ev = queue.submit(move |cgh| {
                let acc = cgh.require(&b, AccessMode::ReadWrite);
                cgh.host_task(format!("k{i}"), CommandClass::Other, kernel(100), move |_| {
                    let _ = acc;
                });
            });
            if ev.profiling_command_start() < last_end {
                return Err(format!("cmd {i} started before predecessor ended"));
            }
            last_end = ev.profiling_command_end();
        }
        Ok(())
    });
}

#[test]
fn prop_usm_dependency_chains_respected() {
    testkit::forall("usm-chains", 25, |g| {
        let queue = Queue::new(random_platform(g), random_profile(g));
        let mut events = Vec::new();
        for i in 0..g.usize_in(2, 15) {
            // Depend on a random subset of earlier events.
            let deps: Vec<_> = events
                .iter()
                .filter(|_| g.bool_with(0.4))
                .cloned()
                .collect();
            let ev = queue.submit_usm(
                format!("u{i}"),
                CommandClass::Other,
                kernel(g.range(1, 1 << 18)),
                &deps,
                vec![],
                |_| {},
            );
            for d in &deps {
                if ev.profiling_command_start() < d.profiling_command_end() {
                    return Err(format!("usm cmd {i} ignored its dependency"));
                }
            }
            events.push(ev);
        }
        Ok(())
    });
}

#[test]
fn prop_host_read_sees_last_write() {
    testkit::forall("host-read-raw", 20, |g| {
        let queue = Queue::new(random_platform(g), random_profile(g));
        let buf = Buffer::<f32>::new(32);
        let val = g.f32_in(0.0, 100.0);
        let b = buf.clone();
        queue.submit(move |cgh| {
            let acc = cgh.require(&b, AccessMode::Write);
            cgh.host_task("w", CommandClass::Other, kernel(32), move |_| {
                acc.lock().iter_mut().for_each(|x| *x = val);
            });
        });
        let out = queue.host_read(&buf);
        if out.iter().any(|&x| x != val) {
            return Err("host_read returned stale data".into());
        }
        Ok(())
    });
}

#[test]
fn noise_is_reproducible_across_runs() {
    let run = || {
        let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
        queue.set_noise_salt(7);
        let buf = Buffer::<f32>::new(64);
        for i in 0..5 {
            let b = buf.clone();
            queue.submit(move |cgh| {
                let acc = cgh.require(&b, AccessMode::ReadWrite);
                cgh.host_task(format!("k{i}"), CommandClass::Other, kernel(1 << 16), move |_| {
                    let _ = acc;
                });
            });
        }
        queue.wait()
    };
    assert_eq!(run(), run());
}
