//! Burner application integration: paper-shape assertions over the
//! platform fleet (the qualitative claims of Figs. 2-4 must hold for any
//! calibration of the models — see DESIGN.md §3 "expected shapes").

use portarng::burner::{
    run_burner, run_burner_auto, run_burner_virtual, BurnerApi, BurnerConfig,
};
use portarng::platform::PlatformId;
use portarng::testkit;

fn cfg(p: PlatformId, api: BurnerApi, batch: usize) -> BurnerConfig {
    let mut c = BurnerConfig::paper_default(p, api, batch);
    c.iterations = 8;
    c
}

fn mean_ms(p: PlatformId, api: BurnerApi, batch: usize) -> f64 {
    run_burner_auto(&cfg(p, api, batch)).unwrap().mean_total_ns() / 1e6
}

#[test]
fn shape1_latency_floor_then_linear_growth() {
    // Fig 2/3: flat in the overhead-dominated region, ~linear past 10^6.
    for p in [PlatformId::A100, PlatformId::Vega56, PlatformId::Rome7742] {
        let t1 = mean_ms(p, BurnerApi::SyclBuffer, 1);
        let t1k = mean_ms(p, BurnerApi::SyclBuffer, 1_000);
        assert!(t1k < t1 * 2.0, "{p:?}: no latency floor ({t1} vs {t1k})");
        let t1e7 = mean_ms(p, BurnerApi::SyclBuffer, 10_000_000);
        let t1e8 = mean_ms(p, BurnerApi::SyclBuffer, 100_000_000);
        let slope = t1e8 / t1e7;
        assert!((5.0..15.0).contains(&slope), "{p:?}: slope {slope}");
    }
}

#[test]
fn shape2_buffer_usm_equal_on_cpus_and_igpu() {
    // Fig 2: "little overhead is introduced when using the USM API versus
    // buffers" on the x86 CPUs and the iGPU.
    for p in [PlatformId::Rome7742, PlatformId::CoreI7_10875H, PlatformId::Uhd630] {
        for batch in [100usize, 100_000, 100_000_000] {
            let b = mean_ms(p, BurnerApi::SyclBuffer, batch);
            let u = mean_ms(p, BurnerApi::SyclUsm, batch);
            let ratio = u / b;
            assert!((0.8..1.25).contains(&ratio), "{p:?}@{batch}: usm/buffer {ratio}");
        }
    }
}

#[test]
fn shape3_hipsycl_usm_beats_native_at_small_batch() {
    // Fig 3a / Table 2 {Vega56}: the hipSYCL port is at par, USM slightly
    // ahead of the native app at small batches.
    let native = mean_ms(PlatformId::Vega56, BurnerApi::Native, 100);
    let usm = mean_ms(PlatformId::Vega56, BurnerApi::SyclUsm, 100);
    assert!(usm < native, "usm {usm} !< native {native}");
    // And converges at 10^8.
    let n8 = mean_ms(PlatformId::Vega56, BurnerApi::Native, 100_000_000);
    let u8_ = mean_ms(PlatformId::Vega56, BurnerApi::SyclUsm, 100_000_000);
    assert!((u8_ / n8 - 1.0).abs() < 0.1, "no convergence: {u8_} vs {n8}");
}

#[test]
fn shape4_dpcpp_usm_penalty_on_a100() {
    // Fig 3b / Table 2 {A100}: DPC++ USM trails native markedly at small
    // batch; buffer stays at par or better.
    let native = mean_ms(PlatformId::A100, BurnerApi::Native, 1_000);
    let buffer = mean_ms(PlatformId::A100, BurnerApi::SyclBuffer, 1_000);
    let usm = mean_ms(PlatformId::A100, BurnerApi::SyclUsm, 1_000);
    assert!(buffer <= native * 1.05, "buffer {buffer} vs native {native}");
    assert!(usm > native * 2.0, "usm {usm} not penalised vs {native}");
    // "Slight overhead at large batch sizes DPC++ USM" (Fig 3b).
    let n8 = mean_ms(PlatformId::A100, BurnerApi::Native, 100_000_000);
    let u8_ = mean_ms(PlatformId::A100, BurnerApi::SyclUsm, 100_000_000);
    let rel = u8_ / n8 - 1.0;
    assert!((-0.05..0.25).contains(&rel), "large-batch usm rel overhead {rel}");
}

#[test]
fn shape5_kernel_durations_equal_occupancy_differs() {
    // Fig 4: generate-kernel duration statistically equal native vs SYCL,
    // occupancy diverging in the 10^2-10^4 region (tpb 1024 vs 256).
    let batch = 10_000usize;
    let nat = run_burner(&cfg(PlatformId::A100, BurnerApi::Native, batch)).unwrap();
    let syc = run_burner(&cfg(PlatformId::A100, BurnerApi::SyclBuffer, batch)).unwrap();
    let d_nat = nat.breakdown.generate_ns as f64;
    let d_syc = syc.breakdown.generate_ns as f64;
    assert!((d_syc / d_nat - 1.0).abs() < 0.35, "durations diverge: {d_nat} vs {d_syc}");
    assert_eq!(nat.breakdown.tpb, 256);
    assert_eq!(syc.breakdown.tpb, 1024);
    assert!(
        syc.breakdown.generate_occupancy > nat.breakdown.generate_occupancy,
        "sycl occupancy {} !> native {}",
        syc.breakdown.generate_occupancy,
        nat.breakdown.generate_occupancy
    );
}

#[test]
fn uma_igpu_has_zero_transfer_cost() {
    let r = run_burner(&cfg(PlatformId::Uhd630, BurnerApi::SyclBuffer, 1 << 20)).unwrap();
    // Zero-copy: D2H recorded but ~free relative to the generate kernel.
    assert!(r.breakdown.d2h_ns < r.breakdown.generate_ns / 10);
}

#[test]
fn prop_virtual_real_consistency() {
    // The virtual path must track the real path for any config under the
    // cap (same structure, same costs).
    testkit::forall("virtual-real", 10, |g| {
        let p = *g.choose(&[PlatformId::A100, PlatformId::Vega56, PlatformId::Rome7742]);
        let api = *g.choose(&[BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm]);
        let batch = g.usize_in(1, 1 << 18);
        let mut c = cfg(p, api, batch);
        c.iterations = 3;
        let real = run_burner(&c).map_err(|e| e.to_string())?.mean_total_ns();
        let virt = run_burner_virtual(&c).map_err(|e| e.to_string())?.mean_total_ns();
        let ratio = real / virt;
        if !(0.7..1.4).contains(&ratio) {
            return Err(format!("{p:?}/{api:?}@{batch}: real/virtual {ratio}"));
        }
        Ok(())
    });
}

#[test]
fn samples_are_valid_uniforms() {
    for api in [BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
        let r = run_burner(&cfg(PlatformId::A100, api, 4096)).unwrap();
        assert!(!r.sample.is_empty(), "{api:?}");
        assert!(r.sample.iter().all(|&x| (0.0..1.0).contains(&x)), "{api:?}");
    }
}
