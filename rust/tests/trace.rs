//! Trace integration (DESIGN.md S18): span-chain well-formedness under a
//! virtual clock, deterministic flight-recorder dumps under op-counted
//! chaos kills, and Chrome-export completeness — all against the public
//! pool API, the way `serve --trace` / `burner --trace` drive it.
//!
//! Ring-tear freedom is pinned at the unit level
//! (`trace::ring::tests::concurrent_overwrite_never_tears_a_span`); this
//! file owns the end-to-end properties.

use std::sync::Arc;
use std::time::Duration;

use portarng::coordinator::{DispatchPolicy, PoolConfig, ServicePool};
use portarng::fault::FaultSpec;
use portarng::platform::PlatformId;
use portarng::trace::{
    self, chrome, Clock, Span, SpanKind, TraceConfig, VirtualClock, NONE_ID,
};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// A trace config on a driver-owned virtual clock: every coordinator
/// span timestamp is deterministic (0 unless the test advances it).
fn virtual_trace(flight_dir: Option<std::path::PathBuf>) -> (TraceConfig, Arc<VirtualClock>) {
    let clock = Arc::new(VirtualClock::new());
    let cfg = TraceConfig {
        capacity: 1 << 14,
        flight_dir,
        clock: Some(clock.clone() as Arc<dyn Clock>),
    };
    (cfg, clock)
}

/// Unique scratch directory for flight dumps (removed by the caller).
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "portarng-trace-{tag}-{}-{}",
        std::process::id(),
        std::thread::current().name().unwrap_or("t").replace("::", "-"),
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spans_of<'a>(spans: &'a [Span], kind: SpanKind) -> impl Iterator<Item = &'a Span> {
    spans.iter().filter(move |s| s.kind == kind)
}

#[test]
fn prop_every_replied_request_has_a_well_formed_span_chain() {
    // The tentpole invariant: for every request that received an Ok
    // reply, the trace holds admit -> stage -> launch -> d2h -> reply in
    // global seq (admission) order, stitched by request_id and the
    // reply's flush_id — and no span names a request that was never
    // admitted (no orphans).
    let (trace_cfg, _clock) = virtual_trace(None);
    let mut cfg = PoolConfig::new(PlatformId::A100, 0x51AB, 2);
    cfg.trace = Some(trace_cfg);
    let pool = ServicePool::spawn(cfg);
    let tracer = pool.tracer().expect("trace configured => tracer exposed");

    let sizes: Vec<usize> = (0..12).map(|i| 64 + 37 * i).collect();
    let rxs: Vec<_> = sizes.iter().map(|&n| pool.generate(n, (0.0, 1.0))).collect();
    pool.flush();
    for rx in rxs {
        rx.recv_timeout(RECV_TIMEOUT).expect("caller hung").expect("clean run errored");
    }
    pool.shutdown().unwrap();
    let spans = tracer.snapshot();

    // Every admitted request is in the trace exactly once.
    let admits: Vec<&Span> = spans_of(&spans, SpanKind::IngressAdmit).collect();
    assert_eq!(admits.len(), sizes.len(), "one admit span per request");

    // No orphans: any request_id on any span was admitted.
    for s in spans.iter().filter(|s| s.request_id != NONE_ID) {
        assert!(
            admits.iter().any(|a| a.request_id == s.request_id),
            "span {} names unadmitted request {}",
            s.kind.token(),
            s.request_id
        );
    }

    for admit in &admits {
        let id = admit.request_id;
        let seq_of = |k: SpanKind| {
            spans_of(&spans, k)
                .find(|s| s.request_id == id)
                .unwrap_or_else(|| panic!("request {id}: missing {} span", k.token()))
                .seq
        };
        let (s_admit, s_stage, s_reply) =
            (seq_of(SpanKind::IngressAdmit), seq_of(SpanKind::BatcherStage), seq_of(SpanKind::ReplySend));
        assert!(s_admit < s_stage && s_stage < s_reply, "request {id}: admit/stage/reply out of order");

        let reply = spans_of(&spans, SpanKind::ReplySend).find(|s| s.request_id == id).unwrap();
        assert_eq!(reply.aux2, 0, "request {id}: clean run produced an error reply");
        assert_ne!(reply.flush_id, NONE_ID, "request {id}: reply not joined to a flush");

        // The flush the reply names: launched on the same shard, after
        // staging and before the reply, with its D2H drained in between.
        let launch = spans_of(&spans, SpanKind::FlushLaunch)
            .find(|s| s.flush_id == reply.flush_id && s.shard == reply.shard)
            .unwrap_or_else(|| panic!("request {id}: flush {} has no launch span", reply.flush_id));
        assert!(s_stage < launch.seq && launch.seq < s_reply, "request {id}: launch outside stage..reply");
        let d2h = spans_of(&spans, SpanKind::CmdD2h)
            .find(|s| s.flush_id == reply.flush_id && s.shard == reply.shard)
            .unwrap_or_else(|| panic!("request {id}: flush {} has no d2h span", reply.flush_id));
        assert!(launch.seq < d2h.seq && d2h.seq < s_reply, "request {id}: d2h outside launch..reply");
        // cmd.* spans carry the hazard-DAG join key (command id).
        assert_ne!(d2h.aux2, NONE_ID, "request {id}: d2h span lost its command id");
    }

    // Counters agree with the snapshot: nothing overwritten at this
    // capacity, so recorded == surfaced.
    assert_eq!(tracer.spans_dropped(), 0);
    assert_eq!(tracer.spans_recorded(), spans.len() as u64);
}

#[test]
fn unconfigured_pool_exposes_no_tracer_and_zero_trace_counters() {
    // Tracing off is the default; the pool must not grow a tracer and
    // the v7 telemetry trace block must stay all-zero.
    let pool = ServicePool::spawn(PoolConfig::new(PlatformId::A100, 0xD0FF, 2));
    assert!(pool.tracer().is_none());
    let rxs: Vec<_> = (0..4).map(|i| pool.generate(100 + i, (0.0, 1.0))).collect();
    pool.flush();
    for rx in rxs {
        rx.recv_timeout(RECV_TIMEOUT).unwrap().unwrap();
    }
    let registry = pool.telemetry().clone();
    pool.shutdown().unwrap();
    let t = registry.snapshot().trace;
    assert!(!t.any(), "untraced pool moved trace counters: {t:?}");
}

/// One traced run under an op-counted kill plan; returns the flight-dump
/// directory (caller removes it) and the merged span snapshot.
fn killed_run(tag: &str) -> (std::path::PathBuf, Vec<Span>, u64) {
    let dir = scratch_dir(tag);
    let (trace_cfg, _clock) = virtual_trace(Some(dir.clone()));
    let spec = FaultSpec::parse("seed=9,rate=0.0,kill=0@2").unwrap();
    let mut cfg = PoolConfig::new(PlatformId::A100, 0xFEED, 2);
    cfg.trace = Some(trace_cfg);
    cfg.fault = Some(spec);
    cfg.ingress.max_retries = 12;
    // Pin routing onto the batched lanes so shard 0 sees the traffic the
    // kill schedule counts, and launch one request per flush so the ring
    // contents at the kill point cannot depend on arrival timing.
    cfg.policy = DispatchPolicy::fixed(800);
    cfg.max_requests = 1;
    let pool = ServicePool::spawn(cfg);
    let tracer = pool.tracer().unwrap();
    let registry = pool.telemetry().clone();
    let rxs: Vec<_> = (0..10).map(|i| pool.generate(200 + 11 * i, (0.0, 1.0))).collect();
    pool.flush();
    for rx in rxs {
        rx.recv_timeout(RECV_TIMEOUT)
            .expect("caller hung across the kill")
            .expect("supervised kill surfaced an error reply");
    }
    pool.shutdown().unwrap();
    let dumps_counted = registry.snapshot().trace.flight_dumps;
    assert_eq!(tracer.flight_dumps(), dumps_counted, "tracer and telemetry disagree on dumps");
    (dir, tracer.snapshot(), dumps_counted)
}

#[test]
fn chaos_kill_leaves_exactly_one_flight_dump_for_the_dead_shard() {
    let (dir, spans, dumps_counted) = killed_run("kill");
    let dumps = trace::read_flight_dumps(&dir);
    assert_eq!(dumps.len(), 1, "one kill => one flight dump, got {}", dumps.len());
    assert_eq!(dumps_counted, 1, "telemetry must count the dump");
    let (path, shard, dump_spans) = &dumps[0];
    assert_eq!(*shard, 0, "dump must name the killed shard");
    assert!(path.file_name().unwrap().to_str().unwrap().starts_with("flight-shard0-"));
    assert!(!dump_spans.is_empty(), "dead shard's ring was empty");
    // The flight recorder drains the dead shard's ring only: every span
    // in the dump — including the last ones before death — is shard 0's.
    for s in dump_spans {
        assert_eq!(s.shard, 0, "foreign span {} leaked into the dump", s.kind.token());
    }
    // The supervisor re-dispatched the dead shard's in-flight requests
    // and recorded it; redispatch counts stay under the per-request cap.
    let redispatches: Vec<&Span> =
        spans_of(&spans, SpanKind::SupervisorRedispatch).filter(|s| s.shard == 0).collect();
    assert!(!redispatches.is_empty(), "kill absorbed without a redispatch span");
    for r in &redispatches {
        assert!(
            r.aux >= 1 && r.aux <= 64,
            "redispatch count {} outside 1..=redispatch_cap",
            r.aux
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flight_dumps_are_byte_identical_across_runs_of_the_same_plan() {
    // The determinism contract: same seeded plan + virtual clock =>
    // byte-identical dump files, run to run.
    let (dir_a, _, _) = killed_run("det-a");
    let (dir_b, _, _) = killed_run("det-b");
    let read = |dir: &std::path::Path| {
        let dumps = trace::read_flight_dumps(dir);
        assert_eq!(dumps.len(), 1);
        std::fs::read(&dumps[0].0).unwrap()
    };
    let (a, b) = (read(&dir_a), read(&dir_b));
    assert!(!a.is_empty());
    assert_eq!(a, b, "flight dump bytes diverged across identical runs");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn chrome_export_has_per_shard_tracks_and_complete_request_chains() {
    // The CI trace-smoke contract, pinned here without the CLI: the
    // exported document parses, names a coordinator track per serving
    // shard, and carries at least one complete request flow (s/t/f
    // arrows) per shard that replied.
    let (trace_cfg, _clock) = virtual_trace(None);
    let shards = 2usize;
    let mut cfg = PoolConfig::new(PlatformId::A100, 0xC4A0, shards);
    cfg.trace = Some(trace_cfg);
    cfg.policy = DispatchPolicy::fixed(800);
    let pool = ServicePool::spawn(cfg);
    let tracer = pool.tracer().unwrap();
    let rxs: Vec<_> = (0..12).map(|i| pool.generate(150 + 13 * i, (0.0, 1.0))).collect();
    pool.flush();
    for rx in rxs {
        rx.recv_timeout(RECV_TIMEOUT).unwrap().unwrap();
    }
    pool.shutdown().unwrap();
    let spans = tracer.snapshot();

    let path = scratch_dir("chrome").join("trace.json");
    chrome::export(&spans, &path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = portarng::jsonlite::Value::parse(&text).expect("export must be valid JSON");
    let events = doc.get("traceEvents").unwrap().as_array().unwrap().clone();
    let _ = std::fs::remove_dir_all(path.parent().unwrap());

    let replied_shards: Vec<u32> = {
        let mut v: Vec<u32> =
            spans_of(&spans, SpanKind::ReplySend).map(|s| s.shard).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    assert!(!replied_shards.is_empty());
    let meta_named = |name: &str| {
        events.iter().any(|e| {
            e.get("ph").and_then(portarng::jsonlite::Value::as_str) == Some("M")
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(portarng::jsonlite::Value::as_str)
                    == Some(name)
        })
    };
    for &sh in &replied_shards {
        assert!(meta_named(&format!("shard {sh}")), "no coordinator track for shard {sh}");
        assert!(meta_named(&format!("queue {sh}")), "no queue track for shard {sh}");
        // A complete chain on this shard: some reply's flow arrows all
        // present — count "f" (finish) arrows landing on the shard.
        let finishes = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(portarng::jsonlite::Value::as_str) == Some("f")
                    && e.get("tid").and_then(portarng::jsonlite::Value::as_usize)
                        == Some(sh as usize)
            })
            .count();
        assert!(finishes >= 1, "shard {sh} replied but has no complete request flow");
    }
    // Arrows come in matched sets: starts == finishes.
    let ph_count = |p: &str| {
        events
            .iter()
            .filter(|e| e.get("ph").and_then(portarng::jsonlite::Value::as_str) == Some(p))
            .count()
    };
    assert_eq!(ph_count("s"), ph_count("f"));
    assert!(ph_count("X") >= spans.len());
}
