//! Adaptive-dispatch subsystem integration: telemetry snapshot schema and
//! round-trips, calibration profiles, and the measure→retune loop end to
//! end against the virtual-clock objective.

use portarng::autotune::{
    best_fixed_threshold, calibrate, virtual_pool_throughput, AutoTuner, CalibrationProfile,
    ProbeWorkload, ProfileStore,
};
use portarng::burner::{run_burner_pooled, BurnerApi, BurnerConfig};
use portarng::coordinator::TuningParams;
use portarng::jsonlite::Value;
use portarng::platform::PlatformId;
use portarng::telemetry::{Lane, TelemetrySnapshot, TELEMETRY_SCHEMA};

#[test]
fn pooled_burner_telemetry_round_trips_and_matches_schema() {
    // What `portarng burner --pool N --stats-json <path>` writes.
    let mut cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::SyclBuffer, 1000);
    cfg.iterations = 5;
    let r = run_burner_pooled(&cfg, 2, 12).unwrap();
    let text = r.telemetry.to_json().to_json();

    // Round-trips through jsonlite...
    let parsed = Value::parse(&text).unwrap();
    let back = TelemetrySnapshot::from_json(&parsed).unwrap();
    assert_eq!(back.to_json().to_json(), text);

    // ...and matches the documented schema.
    assert_eq!(parsed.get("schema").unwrap().as_str().unwrap(), TELEMETRY_SCHEMA);
    assert_eq!(parsed.get("platform").unwrap().as_str().unwrap(), "a100");
    for key in ["uptime_ns", "dispatched_batched", "dispatched_overflow", "retunes"] {
        assert!(parsed.get(key).unwrap().as_f64().is_some(), "missing {key}");
    }
    let shards = parsed.get("shards").unwrap().as_array().unwrap();
    assert_eq!(shards.len(), 2);
    for s in shards {
        for key in ["shard", "requests", "launches", "numbers", "delivered", "failures"] {
            assert!(s.get(key).unwrap().as_f64().is_some(), "missing {key}");
        }
        assert!(Lane::parse(s.get("lane").unwrap().as_str().unwrap()).is_some());
        for key in ["launch_ns", "batch_fill", "request_n"] {
            let h = s.get(key).unwrap();
            assert!(h.get("count").unwrap().as_f64().is_some());
            assert!(h.get("sum").unwrap().as_f64().is_some());
            assert!(h.get("buckets").unwrap().as_array().is_some());
        }
    }

    // The counters agree with the burner's own accounting.
    assert_eq!(back.total_requests(), 12);
    assert_eq!(back.total_delivered(), 12_000);
    assert_eq!(back.total_failures(), 0);
}

#[test]
fn checked_in_example_profile_parses_and_warm_starts() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../profiles/example_profile.json");
    let store = ProfileStore::load(&path).unwrap();
    assert!(!store.is_empty(), "example profile must not silently load as empty");
    let a100 = store.get(PlatformId::A100).expect("example covers a100");
    assert!(a100.params.threshold > 1);
    assert!(a100.params.flush_requests >= 1);
    assert!(a100.mnum_per_s > 0.0);
    // A warm start uses the stored knobs verbatim: they must be valid
    // TuningParams for a pool.
    assert!(a100.params.policy().is_enabled());
}

#[test]
fn profile_store_round_trips_calibration_output() {
    let profile = calibrate(PlatformId::Vega56, 4);
    let mut store = ProfileStore::new();
    store.put(profile.clone());
    let text = store.to_json().to_json();
    let back = ProfileStore::from_json(&Value::parse(&text).unwrap()).unwrap();
    assert_eq!(back.get(PlatformId::Vega56), Some(&profile));
}

#[test]
fn calibration_beats_the_static_endpoints() {
    // The probe's whole point: the calibrated knobs outperform both "no
    // overflow lane" and "overflow everything" on the probe mix.
    let wl = ProbeWorkload::serving_mix(0xCA11_B007, 192);
    let profile = calibrate(PlatformId::A100, 4);
    let tuned = virtual_pool_throughput(PlatformId::A100, 4, &profile.params, &wl);
    let none = TuningParams { threshold: usize::MAX, ..profile.params };
    let all = TuningParams { threshold: 1, ..profile.params };
    assert!(tuned > virtual_pool_throughput(PlatformId::A100, 4, &none, &wl));
    assert!(tuned > virtual_pool_throughput(PlatformId::A100, 4, &all, &wl));
}

#[test]
fn online_tuner_recovers_miscalibration_against_virtual_objective() {
    // The bench gate's scenario at test scale: mis-specified start, the
    // tuner only sees throughput numbers, must reach 90% of the scan
    // oracle.
    let platform = PlatformId::A100;
    let wl = ProbeWorkload::serving_mix(77, 96);
    let defaults = TuningParams {
        threshold: usize::MAX,
        flush_requests: 16,
        max_batch: 1 << 20,
        tile_size: 0,
        team_width: 1,
    };
    let (_, oracle) = best_fixed_threshold(platform, 4, &defaults, &wl);

    let mut tuner = AutoTuner::new(TuningParams { threshold: 1 << 26, ..defaults });
    let mut params = tuner.params();
    for _ in 0..80 {
        params = tuner.observe(virtual_pool_throughput(platform, 4, &params, &wl));
    }
    assert!(tuner.converged());
    let (best, _) = tuner.best();
    let recovered = virtual_pool_throughput(platform, 4, &best, &wl) / oracle;
    assert!(recovered >= 0.9, "recovered only {:.0}%", recovered * 100.0);
}

#[test]
fn profile_json_threshold_survives_extreme_values() {
    // usize::MAX (disabled threshold) must survive the f64 JSON number
    // representation by saturating back, not wrapping.
    let mut store = ProfileStore::new();
    store.put(CalibrationProfile {
        platform: PlatformId::Rome7742,
        shards: 4,
        params: TuningParams {
            threshold: usize::MAX,
            flush_requests: 16,
            max_batch: 1 << 20,
            tile_size: 0,
            team_width: 1,
        },
        mnum_per_s: 1.0,
        source: "probe".into(),
    });
    let text = store.to_json().to_json();
    let back = ProfileStore::from_json(&Value::parse(&text).unwrap()).unwrap();
    let p = back.get(PlatformId::Rome7742).unwrap();
    assert_eq!(p.params.threshold, usize::MAX);
}
