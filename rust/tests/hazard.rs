//! Hazard-analyzer integration suite (DESIGN.md S14): the existing
//! generate corpus must prove race-free, deliberately broken submissions
//! must yield exactly the expected typed diagnostics, and debug-mode
//! enforcement must turn a dirty window into a panic at the sync point.

use portarng::backends::{CurandBackend, RngBackend};
use portarng::platform::{CommandCost, PlatformId};
use portarng::rng::{
    generate_batch_usm, generate_buffer, generate_usm, BatchSlice, Distribution, EngineKind,
};
use portarng::sycl::{
    analyze_hazards, Access, AccessMode, CommandClass, Dag, HazardKind, Queue,
    SyclRuntimeProfile, UsmArena,
};
use portarng::testkit;

fn queue() -> Queue {
    Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp)
}

fn philox(seed: u64) -> Box<dyn portarng::backends::VendorGenerator> {
    CurandBackend::new().create_generator(EngineKind::Philox4x32x10, seed).unwrap()
}

fn kernel_cost(items: u64) -> CommandCost {
    CommandCost::Kernel { bytes_read: 0, bytes_written: items * 4, items, tpb: 0 }
}

// ---------------------------------------------------------------------------
// The existing corpus proves race-free.
// ---------------------------------------------------------------------------

#[test]
fn buffer_generate_corpus_is_clean() {
    let q = queue();
    let mut gen = philox(7);
    let buf = portarng::sycl::Buffer::<f32>::new(1024);
    generate_buffer(&q, &mut gen, Distribution::uniform(-1.0, 1.0), 1024, &buf).unwrap();
    let _ = q.host_read(&buf);
    q.wait(); // panics here under enforcement if the accessor-derived DAG raced
    let records = q.drain_records();
    let dag = Dag::new(&records);
    dag.validate().unwrap();
    assert!(dag.analyze_hazards().is_clean());
}

#[test]
fn usm_generate_corpus_is_clean() {
    let q = queue();
    let mut gen = philox(8);
    let usm = q.malloc_device::<f32>(1024);
    let ev = generate_usm(&q, &mut gen, Distribution::uniform(0.0, 4.0), 1024, &usm, &[]).unwrap();
    let _ = q.usm_to_host(&usm, std::slice::from_ref(&ev));
    q.wait();
    let report = analyze_hazards(&q.drain_records());
    assert!(report.is_clean(), "{}", report.pretty());
}

// ---------------------------------------------------------------------------
// Negative suite: each broken shape yields exactly its typed diagnostic.
// ---------------------------------------------------------------------------

#[test]
fn omitted_depends_on_is_exactly_one_unordered_d2h() {
    let q = queue();
    let mut gen = philox(9);
    let usm = q.malloc_device::<f32>(256);
    // Canonical range: the generate kernel is the only producer.
    let _ev = generate_usm(&q, &mut gen, Distribution::uniform(0.0, 1.0), 256, &usm, &[]).unwrap();
    // The §4.1 footgun: reading back without the event chain.
    let _ = q.usm_to_host(&usm, &[]);
    let report = analyze_hazards(&q.records());
    assert_eq!(report.hazards.len(), 1, "{}", report.pretty());
    assert_eq!(report.count_of(HazardKind::UnorderedD2h), 1);
}

#[test]
fn forged_lease_generation_is_exactly_one_lease_reuse() {
    let q = queue();
    let usm = q.malloc_device::<f32>(64);
    // Two writers claiming different lease generations with no ordering
    // path: a recycled buffer whose pending events were never inherited.
    q.submit_usm(
        "flush0",
        CommandClass::Generate,
        kernel_cost(64),
        &[],
        vec![Access::usm_leased(usm.id(), AccessMode::Write, Some(0))],
        |_| {},
    );
    q.submit_usm(
        "flush1",
        CommandClass::Generate,
        kernel_cost(64),
        &[],
        vec![Access::usm_leased(usm.id(), AccessMode::Write, Some(1))],
        |_| {},
    );
    let report = analyze_hazards(&q.records());
    assert_eq!(report.hazards.len(), 1, "{}", report.pretty());
    assert_eq!(report.count_of(HazardKind::LeaseReuse), 1);
}

#[test]
fn stale_generation_is_flagged_even_with_an_ordering_path() {
    let q = queue();
    let usm = q.malloc_device::<f32>(64);
    let ev = q.submit_usm(
        "current",
        CommandClass::Generate,
        kernel_cost(64),
        &[],
        vec![Access::usm_leased(usm.id(), AccessMode::Write, Some(2))],
        |_| {},
    );
    // Properly chained, but holding a handle from before the recycle.
    q.submit_usm(
        "stale-holder",
        CommandClass::Generate,
        kernel_cost(64),
        std::slice::from_ref(&ev),
        vec![Access::usm_leased(usm.id(), AccessMode::Write, Some(1))],
        |_| {},
    );
    let report = analyze_hazards(&q.records());
    assert_eq!(report.hazards.len(), 1, "{}", report.pretty());
    assert_eq!(report.count_of(HazardKind::StaleLease), 1);
}

#[test]
fn missing_pending_inheritance_across_recycle_classifies_all_three_ways() {
    // Two single-member canonical flushes through one recycled launch
    // buffer, with the second flush *dropping* the lease's pending events:
    // gen0 -> d2h0 (chained), gen1 -> d2h1 (chained), nothing across.
    let q = queue();
    let mut gen = philox(10);
    let arena: UsmArena<f32> = UsmArena::new();
    let member = |off: u64| BatchSlice {
        buffer_offset: 0,
        stream_offset: off,
        n: 128,
        range: (0.0, 1.0),
    };

    let mut lease = arena.checkout(&q, 128);
    let batch = generate_batch_usm(
        &q,
        gen.as_mut(),
        &[member(0)],
        128,
        lease.buffer(),
        Some(lease.generation()),
        &[],
    )
    .unwrap();
    lease.set_pending(batch.last_events());
    lease.recycle();

    let lease = arena.checkout(&q, 128);
    assert_eq!(lease.generation(), 1);
    let _ = generate_batch_usm(
        &q,
        gen.as_mut(),
        &[member(128)],
        128,
        lease.buffer(),
        Some(lease.generation()),
        &[], // BUG under test: lease.deps() discarded
    )
    .unwrap();
    lease.recycle();

    let report = analyze_hazards(&q.records());
    // gen0 vs gen1: cross-generation writers -> LeaseReuse.
    assert_eq!(report.count_of(HazardKind::LeaseReuse), 1, "{}", report.pretty());
    // gen0 (write) vs flush-1's D2H slice read -> the D2H special case.
    assert_eq!(report.count_of(HazardKind::UnorderedD2h), 1, "{}", report.pretty());
    // flush-0's D2H slice read vs gen1 (write) -> WAR.
    assert_eq!(report.count_of(HazardKind::War), 1, "{}", report.pretty());
    assert_eq!(report.hazards.len(), 3, "{}", report.pretty());
}

#[test]
fn dangling_and_duplicate_edges_are_detected() {
    use portarng::sycl::CommandRecord;
    let rec = |id: u64, deps: &[u64]| CommandRecord {
        id,
        name: format!("c{id}"),
        class: CommandClass::Other,
        dep_ids: deps.to_vec(),
        virt_start_ns: id * 10,
        virt_end_ns: id * 10 + 5,
        wall_ns: 0,
        tpb: None,
        occupancy: None,
        accesses: vec![],
    };
    // Window floor is 20: the dep on 4 is an external (drained) edge, the
    // dep on 777 is dangling, and the repeated id 21 is a collision.
    let records =
        [rec(20, &[4]), rec(21, &[20]), rec(21, &[20]), rec(22, &[21, 777])];
    let report = analyze_hazards(&records);
    assert_eq!(report.external_deps, 1);
    assert_eq!(report.count_of(HazardKind::DanglingDep), 1);
    assert_eq!(report.count_of(HazardKind::DuplicateId), 1);
    assert_eq!(report.hazards.len(), 2, "{}", report.pretty());

    let dag = Dag::new(&records);
    assert!(dag.validate().unwrap_err().contains("duplicate command id"));
}

// ---------------------------------------------------------------------------
// Enforcement: dirty windows panic at sync points when the check is on.
// ---------------------------------------------------------------------------

#[test]
fn enforcement_panics_on_wait_over_a_dirty_window() {
    if !Queue::hazard_check_enabled() {
        return; // release run without PORTARNG_HAZARD_CHECK=1
    }
    let q = queue();
    let mut gen = philox(11);
    let usm = q.malloc_device::<f32>(128);
    let _ = generate_usm(&q, &mut gen, Distribution::uniform(0.0, 1.0), 128, &usm, &[]).unwrap();
    let _ = q.usm_to_host(&usm, &[]); // missing event chain
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        q.wait();
    }))
    .unwrap_err();
    let msg = err
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("unordered-d2h"), "unexpected panic payload: {msg}");
}

#[test]
fn in_order_queues_are_exempt_from_enforcement() {
    // Same dirty shape, but an in-order queue serialises submissions by
    // construction — unordered record pairs are not races there, and the
    // sync point must not panic.
    let q = Queue::in_order(PlatformId::Rome7742, SyclRuntimeProfile::Dpcpp);
    let mut gen = philox(12);
    let usm = q.malloc_device::<f32>(128);
    let _ = generate_usm(&q, &mut gen, Distribution::uniform(0.0, 1.0), 128, &usm, &[]).unwrap();
    let _ = q.usm_to_host(&usm, &[]);
    q.wait();
    let _ = q.drain_records();
}

// ---------------------------------------------------------------------------
// Satellite (c): arena checkout/recycle under stale pending events, pinned
// by the analyzer as a property over random flush sequences.
// ---------------------------------------------------------------------------

#[test]
fn prop_arena_flush_sequences_prove_race_free() {
    testkit::forall("arena-flush-hazards", 20, |g| {
        let q = queue();
        let mut gen = philox(g.u64());
        let arena: UsmArena<f32> = UsmArena::new();
        let flushes = g.usize_in(2, 5);
        let mut offset = 0u64;
        for _ in 0..flushes {
            let members: Vec<BatchSlice> = (0..g.usize_in(1, 4))
                .map(|i| {
                    let n = g.usize_in(16, 256);
                    let m = BatchSlice {
                        buffer_offset: i * 256,
                        stream_offset: offset,
                        n,
                        range: if g.bool_with(0.5) { (0.0, 1.0) } else { (-2.0, 2.0) },
                    };
                    offset += n as u64;
                    m
                })
                .collect();
            let launch_n = members.len() * 256;
            let mut lease = arena.checkout(&q, launch_n);
            // The lease carries the previous tenant's pending events even
            // when they are long finished ("stale" in wall time) — the
            // chain must still be threaded for the proof to hold.
            let deps = lease.deps().to_vec();
            let batch = generate_batch_usm(
                &q,
                gen.as_mut(),
                &members,
                launch_n,
                lease.buffer(),
                Some(lease.generation()),
                &deps,
            )
            .map_err(|e| e.to_string())?;
            for p in &batch.payloads {
                if let Err(e) = p {
                    return Err(format!("member failed: {e}"));
                }
            }
            lease.set_pending(batch.last_events());
            lease.recycle();
        }
        q.wait(); // enforcement sync point (debug builds)
        let records = q.drain_records();
        let report = analyze_hazards(&records);
        if !report.is_clean() {
            return Err(format!("chained flush sequence reported: {}", report.pretty()));
        }
        let dag = Dag::new(&records);
        dag.validate()?;

        // Adversarial twin: replay the same shape with the pending chain
        // severed at one random flush — the analyzer must notice.
        let q2 = queue();
        let mut gen2 = philox(g.u64());
        let arena2: UsmArena<f32> = UsmArena::new();
        let broken_at = g.usize_in(1, flushes - 1);
        for flush in 0..flushes {
            let mut lease = arena2.checkout(&q2, 256);
            let deps = if flush == broken_at { Vec::new() } else { lease.deps().to_vec() };
            let batch = generate_batch_usm(
                &q2,
                gen2.as_mut(),
                &[BatchSlice {
                    buffer_offset: 0,
                    stream_offset: flush as u64 * 256,
                    n: 256,
                    range: (0.0, 1.0),
                }],
                256,
                lease.buffer(),
                Some(lease.generation()),
                &deps,
            )
            .map_err(|e| e.to_string())?;
            lease.set_pending(batch.last_events());
            lease.recycle();
        }
        let report = analyze_hazards(&q2.records());
        if report.count_of(HazardKind::LeaseReuse) == 0 {
            return Err(format!(
                "severed chain at flush {broken_at} went undetected: {}",
                report.pretty()
            ));
        }
        Ok(())
    });
}
