//! FastCaloSim integration: physics sanity, the Fig. 5 shape claims, and
//! the S17 serving-path determinism properties (standalone vs pooled,
//! native vs SYCL, chaos vs control).

use portarng::fastcalosim::{
    run_fastcalosim, run_fastcalosim_pooled, FcsApi, FcsConfig, Simulator, Workload,
};
use portarng::fault::FaultSpec;
use portarng::platform::PlatformId;

#[test]
fn fig5_shape_gpu_advantage_shrinks_for_ttbar() {
    // §7: ~80% reduction on GPUs for single-e; the advantage shrinks for
    // t t̄ (no inter-event parallelism, parameterization churn).
    let se = Workload::SingleElectron { events: 20 };
    let tt = Workload::TTbar { events: 5 };
    let cpu_se = run_fastcalosim(PlatformId::Rome7742, FcsApi::Sycl, se, 1).unwrap();
    let gpu_se = run_fastcalosim(PlatformId::A100, FcsApi::Sycl, se, 1).unwrap();
    let cpu_tt = run_fastcalosim(PlatformId::Rome7742, FcsApi::Sycl, tt, 1).unwrap();
    let gpu_tt = run_fastcalosim(PlatformId::A100, FcsApi::Sycl, tt, 1).unwrap();

    let red_se = 1.0 - gpu_se.mean_event_ms() / cpu_se.mean_event_ms();
    let red_tt = 1.0 - gpu_tt.mean_event_ms() / cpu_tt.mean_event_ms();
    assert!((0.6..0.95).contains(&red_se), "single-e GPU reduction {red_se}");
    assert!(red_tt < red_se, "t t̄ advantage {red_tt} !< single-e {red_se}");
}

#[test]
fn fig5_shape_sycl_at_par_with_native_everywhere() {
    for p in [PlatformId::A100, PlatformId::Rome7742, PlatformId::CoreI7_10875H] {
        let w = Workload::SingleElectron { events: 10 };
        let nat = run_fastcalosim(p, FcsApi::Native, w, 2).unwrap();
        let syc = run_fastcalosim(p, FcsApi::Sycl, w, 2).unwrap();
        let eff = nat.mean_event_ms() / syc.mean_event_ms();
        assert!((0.75..1.35).contains(&eff), "{p:?}: VAVS {eff}");
    }
}

#[test]
fn ttbar_paramterization_traffic() {
    let tt = run_fastcalosim(
        PlatformId::A100,
        FcsApi::Sycl,
        Workload::TTbar { events: 10 },
        7,
    )
    .unwrap();
    assert!((20..=36).contains(&tt.tables_loaded), "tables {}", tt.tables_loaded);
    // RN volume: O(10^7) scale territory for the full 500-event run; for
    // 10 events demand the proportional slice.
    assert!(tt.rns > 10 * 200_000, "rns {}", tt.rns);
}

#[test]
fn rn_floor_applies_per_event() {
    let se = run_fastcalosim(
        PlatformId::A100,
        FcsApi::Sycl,
        Workload::SingleElectron { events: 7 },
        3,
    )
    .unwrap();
    // 3*hits < 200k for single electrons -> the per-event floor dominates.
    assert_eq!(se.rns, 7 * 200_000);
}

#[test]
fn deposits_land_near_shower_centre() {
    let events = Workload::SingleElectron { events: 3 }.events(11);
    let mut sim = Simulator::new(FcsConfig::new(PlatformId::A100, FcsApi::Sycl));
    sim.simulate(&events).unwrap();
    let deposits = sim.deposits();
    let nonzero = deposits.iter().filter(|&&x| x > 0.0).count();
    // Electrons in a tight cone: thousands of cells, not the whole detector.
    assert!(nonzero > 50, "nonzero {nonzero}");
    assert!(nonzero < deposits.len() / 10, "shower too wide: {nonzero}");
}

#[test]
fn determinism_same_seed_same_result() {
    let w = Workload::TTbar { events: 3 };
    let a = run_fastcalosim(PlatformId::Vega56, FcsApi::Sycl, w, 5).unwrap();
    let b = run_fastcalosim(PlatformId::Vega56, FcsApi::Sycl, w, 5).unwrap();
    assert_eq!(a.total_ns, b.total_ns);
    assert_eq!(a.hits, b.hits);
    assert_eq!(a.energy_dep, b.energy_dep);
}

#[test]
fn pooled_bit_identical_to_standalone_for_any_shard_and_tile_shape() {
    // The S17 acceptance property: routing every draw through the sharded
    // ServicePool must not move a single bit of physics — for any shard
    // count or tile-executor shape — and must not perturb the virtual
    // clock either (the pool is a host-side serving detail).
    let w = Workload::SingleElectron { events: 4 };
    let standalone = run_fastcalosim(PlatformId::A100, FcsApi::Sycl, w, 9).unwrap();
    assert_eq!(standalone.source, "host");
    for shards in [1usize, 4] {
        for tiling in [None, Some((256, 2))] {
            let pooled = run_fastcalosim_pooled(
                PlatformId::A100,
                FcsApi::Sycl,
                w,
                9,
                shards,
                tiling,
                None,
            )
            .unwrap();
            let r = &pooled.report;
            assert_eq!(r.source, "pooled");
            assert_eq!(
                r.checksum, standalone.checksum,
                "physics diverged (shards={shards}, tiling={tiling:?})"
            );
            assert_eq!(r.hits, standalone.hits);
            assert_eq!(r.rns, standalone.rns);
            assert_eq!(r.energy_dep.to_bits(), standalone.energy_dep.to_bits());
            assert_eq!(r.total_ns, standalone.total_ns, "virtual clock moved");
            assert_eq!(pooled.stats.shards.len(), shards);
            assert!(pooled.telemetry.total_delivered() > 0);
        }
    }
}

#[test]
fn native_and_sycl_ports_share_physics() {
    // Port choice moves timing, never physics: identical hit counts and
    // deposit checksums for the same seed on every platform.
    for p in [PlatformId::A100, PlatformId::Rome7742] {
        let w = Workload::SingleElectron { events: 4 };
        let nat = run_fastcalosim(p, FcsApi::Native, w, 13).unwrap();
        let syc = run_fastcalosim(p, FcsApi::Sycl, w, 13).unwrap();
        assert_eq!(nat.checksum, syc.checksum, "{p:?}: ports disagree on physics");
        assert_eq!(nat.hits, syc.hits);
        assert_eq!(nat.rns, syc.rns);
        assert_eq!(nat.energy_dep.to_bits(), syc.energy_dep.to_bits());
    }
}

#[test]
fn chaos_pooled_run_matches_fault_free_control() {
    // Kills + transient faults must be absorbed by the supervisor with
    // bit-identical replies (skip-ahead regeneration from recorded
    // offsets) — the chaos run's physics equals the fault-free control.
    let w = Workload::SingleElectron { events: 3 };
    let control =
        run_fastcalosim_pooled(PlatformId::A100, FcsApi::Sycl, w, 21, 2, None, None).unwrap();
    let chaos_plan = FaultSpec::parse("seed=7,rate=0.02,kill=0@3").unwrap();
    let chaos = run_fastcalosim_pooled(
        PlatformId::A100,
        FcsApi::Sycl,
        w,
        21,
        2,
        None,
        Some(chaos_plan),
    )
    .unwrap();
    assert_eq!(chaos.report.checksum, control.report.checksum, "chaos changed physics");
    assert_eq!(chaos.report.hits, control.report.hits);
    let res = chaos.telemetry.resilience_totals();
    assert!(res.faults_injected > 0, "plan never fired — the soak is vacuous");
    assert!(!control.telemetry.resilience_totals().any(), "control saw faults");
}

#[test]
fn pooled_telemetry_v6_round_trips_with_event_splits() {
    let w = Workload::SingleElectron { events: 3 };
    let run =
        run_fastcalosim_pooled(PlatformId::A100, FcsApi::Sycl, w, 17, 2, None, None).unwrap();
    let fcs = run.telemetry.fcs;
    assert_eq!(fcs.events, 3);
    assert!(fcs.hits > 0);
    assert!(fcs.gen_ns > 0, "generate split empty");
    assert!(fcs.transform_ns > 0, "transform split empty");
    assert!(fcs.d2h_ns > 0, "d2h split empty");
    let json = run.telemetry.to_json().to_json();
    assert!(json.contains("portarng-telemetry-v7"));
    let back = portarng::telemetry::TelemetrySnapshot::from_json(
        &portarng::jsonlite::Value::parse(&json).unwrap(),
    )
    .unwrap();
    assert_eq!(back.fcs, fcs);
}

#[test]
fn no_native_port_for_vega_matches_paper() {
    // The paper has no native HIP FastCaloSim port; our simulator will run
    // it (useful for ablation) but the fig5 driver skips it — assert the
    // driver behaviour.
    let tables = portarng::repro::ExperimentId::parse("fig5");
    assert!(tables.is_some());
}
