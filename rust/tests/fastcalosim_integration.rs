//! FastCaloSim integration: physics sanity + the Fig. 5 shape claims.

use portarng::fastcalosim::{run_fastcalosim, FcsApi, Simulator, FcsConfig, Workload};
use portarng::platform::PlatformId;

#[test]
fn fig5_shape_gpu_advantage_shrinks_for_ttbar() {
    // §7: ~80% reduction on GPUs for single-e; the advantage shrinks for
    // t t̄ (no inter-event parallelism, parameterization churn).
    let se = Workload::SingleElectron { events: 20 };
    let tt = Workload::TTbar { events: 5 };
    let cpu_se = run_fastcalosim(PlatformId::Rome7742, FcsApi::Sycl, se, 1).unwrap();
    let gpu_se = run_fastcalosim(PlatformId::A100, FcsApi::Sycl, se, 1).unwrap();
    let cpu_tt = run_fastcalosim(PlatformId::Rome7742, FcsApi::Sycl, tt, 1).unwrap();
    let gpu_tt = run_fastcalosim(PlatformId::A100, FcsApi::Sycl, tt, 1).unwrap();

    let red_se = 1.0 - gpu_se.mean_event_ms() / cpu_se.mean_event_ms();
    let red_tt = 1.0 - gpu_tt.mean_event_ms() / cpu_tt.mean_event_ms();
    assert!((0.6..0.95).contains(&red_se), "single-e GPU reduction {red_se}");
    assert!(red_tt < red_se, "t t̄ advantage {red_tt} !< single-e {red_se}");
}

#[test]
fn fig5_shape_sycl_at_par_with_native_everywhere() {
    for p in [PlatformId::A100, PlatformId::Rome7742, PlatformId::CoreI7_10875H] {
        let w = Workload::SingleElectron { events: 10 };
        let nat = run_fastcalosim(p, FcsApi::Native, w, 2).unwrap();
        let syc = run_fastcalosim(p, FcsApi::Sycl, w, 2).unwrap();
        let eff = nat.mean_event_ms() / syc.mean_event_ms();
        assert!((0.75..1.35).contains(&eff), "{p:?}: VAVS {eff}");
    }
}

#[test]
fn ttbar_paramterization_traffic() {
    let tt = run_fastcalosim(
        PlatformId::A100,
        FcsApi::Sycl,
        Workload::TTbar { events: 10 },
        7,
    )
    .unwrap();
    assert!((20..=36).contains(&tt.tables_loaded), "tables {}", tt.tables_loaded);
    // RN volume: O(10^7) scale territory for the full 500-event run; for
    // 10 events demand the proportional slice.
    assert!(tt.rns > 10 * 200_000, "rns {}", tt.rns);
}

#[test]
fn rn_floor_applies_per_event() {
    let se = run_fastcalosim(
        PlatformId::A100,
        FcsApi::Sycl,
        Workload::SingleElectron { events: 7 },
        3,
    )
    .unwrap();
    // 3*hits < 200k for single electrons -> the per-event floor dominates.
    assert_eq!(se.rns, 7 * 200_000);
}

#[test]
fn deposits_land_near_shower_centre() {
    let events = Workload::SingleElectron { events: 3 }.events(11);
    let mut sim = Simulator::new(FcsConfig::new(PlatformId::A100, FcsApi::Sycl));
    sim.simulate(&events).unwrap();
    let deposits = sim.deposits();
    let nonzero = deposits.iter().filter(|&&x| x > 0.0).count();
    // Electrons in a tight cone: thousands of cells, not the whole detector.
    assert!(nonzero > 50, "nonzero {nonzero}");
    assert!(nonzero < deposits.len() / 10, "shower too wide: {nonzero}");
}

#[test]
fn determinism_same_seed_same_result() {
    let w = Workload::TTbar { events: 3 };
    let a = run_fastcalosim(PlatformId::Vega56, FcsApi::Sycl, w, 5).unwrap();
    let b = run_fastcalosim(PlatformId::Vega56, FcsApi::Sycl, w, 5).unwrap();
    assert_eq!(a.total_ns, b.total_ns);
    assert_eq!(a.hits, b.hits);
    assert_eq!(a.energy_dep, b.energy_dep);
}

#[test]
fn no_native_port_for_vega_matches_paper() {
    // The paper has no native HIP FastCaloSim port; our simulator will run
    // it (useful for ablation) but the fig5 driver skips it — assert the
    // driver behaviour.
    let tables = portarng::repro::ExperimentId::parse("fig5");
    assert!(tables.is_some());
}
