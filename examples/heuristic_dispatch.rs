//! The paper's §8 future-work extension, implemented: heuristic
//! host-vs-device backend selection by problem size, plus the batching
//! RNG service that keeps small requests off the device entirely.
//!
//! ```bash
//! cargo run --release --example heuristic_dispatch
//! ```

use portarng::coordinator::{BackendHeuristic, RngService};
use portarng::platform::PlatformId;

fn main() -> anyhow::Result<()> {
    println!("== §8 heuristic backend selection ==\n");
    for (device, host) in [
        (PlatformId::A100, PlatformId::Rome7742),
        (PlatformId::Vega56, PlatformId::XeonGold5220),
    ] {
        let h = BackendHeuristic::calibrate(device, host);
        println!(
            "{:<10} vs {:<10}: crossover at {:>9} numbers",
            device.token(),
            host.token(),
            h.crossover
        );
        for batch in [100usize, 10_000, 1_000_000, 100_000_000] {
            println!("    batch {:>11} -> {}", batch, h.select(batch).token());
        }
    }

    println!("\n== batching service (coalesces small requests) ==\n");
    let svc = RngService::spawn(PlatformId::A100, 0x5EED, 1 << 16, 8);
    let receivers: Vec<_> = (0..24).map(|i| svc.generate(500 + i * 16, (0.0, 1.0))).collect();
    svc.flush();
    let mut total = 0;
    for rx in receivers {
        total += rx.recv()??.len();
    }
    let stats = svc.shutdown()?;
    println!(
        "{} requests ({} numbers) served by {} kernel launches — {:.1} requests/launch",
        stats.requests,
        total,
        stats.launches,
        stats.requests as f64 / stats.launches as f64
    );
    Ok(())
}
