//! The paper's §8 future-work extension, implemented: heuristic
//! host-vs-device backend selection by problem size, plus the sharded
//! batching service pool that keeps small requests off the device
//! entirely and gives large ones a dedicated overflow lane.
//!
//! ```bash
//! cargo run --release --example heuristic_dispatch
//! ```

use portarng::coordinator::{BackendHeuristic, DispatchPolicy, PoolConfig, ServicePool};
use portarng::platform::PlatformId;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("== §8 heuristic backend selection ==\n");
    let mut a100_crossover = 100_000;
    for (device, host) in [
        (PlatformId::A100, PlatformId::Rome7742),
        (PlatformId::Vega56, PlatformId::XeonGold5220),
    ] {
        let h = BackendHeuristic::calibrate(device, host);
        if device == PlatformId::A100 {
            a100_crossover = h.crossover;
        }
        println!(
            "{:<10} vs {:<10}: crossover at {:>9} numbers",
            device.token(),
            host.token(),
            h.crossover
        );
        for batch in [100usize, 10_000, 1_000_000, 100_000_000] {
            println!("    batch {:>11} -> {}", batch, h.select(batch).token());
        }
    }

    println!("\n== sharded service pool (coalesces small, overflows large) ==\n");
    let mut cfg = PoolConfig::new(PlatformId::A100, 0x5EED, 4);
    cfg.max_batch = 1 << 16;
    cfg.max_requests = 8;
    cfg.policy = DispatchPolicy::fixed(a100_crossover.min(1 << 16));
    let pool = ServicePool::spawn(cfg);

    let mut receivers = Vec::new();
    for i in 0..24 {
        receivers.push(pool.generate(500 + i * 16, (0.0, 1.0))); // batched lanes
    }
    receivers.push(pool.generate(1 << 20, (0.0, 1.0))); // overflow lane
    pool.flush();
    let mut total = 0;
    for rx in receivers {
        total += rx.recv()??.len();
    }
    let stats = pool.shutdown()?;
    let t = stats.total();
    println!(
        "{} requests ({} numbers) served by {} kernel launches across {} shards — \
         {:.1} requests/launch",
        t.requests,
        total,
        t.launches,
        stats.shards.len(),
        t.requests as f64 / t.launches as f64
    );
    for (i, s) in stats.shards.iter().enumerate() {
        let role = if i + 1 == stats.shards.len() { "overflow" } else { "batched" };
        println!("  shard {i} ({role}): {} requests in {} launches", s.requests, s.launches);
    }
    Ok(())
}
