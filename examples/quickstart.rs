//! Quickstart: the portable RNG API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates uniform and gaussian batches through the oneMKL-like front-end
//! on three different "vendor" backends and shows that (a) the numbers are
//! identical (same engine, same seed — the portability promise) and (b)
//! each platform's virtual cost differs (the performance model).

use portarng::burner::native_backend_for;
use portarng::platform::PlatformId;
use portarng::rng::{generate_buffer, Distribution, EngineKind};
use portarng::sycl::{Buffer, Queue, SyclRuntimeProfile};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 10_000;
    let distr = Distribution::uniform(-1.0, 1.0);

    println!("generating {n} uniforms in [-1, 1) on three platforms:\n");
    let mut outputs = Vec::new();
    for platform in [PlatformId::A100, PlatformId::Vega56, PlatformId::CoreI7_10875H] {
        // A SYCL queue on the target platform with its paper-matching
        // compiler runtime (DPC++ or hipSYCL).
        let queue = Queue::new(platform, SyclRuntimeProfile::for_platform(&platform.spec()));

        // The vendor backend the oneMKL interop layer glues in there.
        let backend = native_backend_for(platform);
        let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 42)?;

        // Listing 1.1 + 1.2: interop generate kernel + range transform.
        let buf = Buffer::<f32>::new(n);
        generate_buffer(&queue, &mut gen, distr, n, &buf)?;
        let out = queue.host_read(&buf);
        let total_ms = queue.wait() as f64 / 1e6;

        println!(
            "  {:<28} via {:<12} -> first 4: {:?}  ({total_ms:.3} ms virtual)",
            platform.spec().name,
            backend.name(),
            &out[..4]
        );
        outputs.push(out);
    }

    assert!(outputs.windows(2).all(|w| w[0] == w[1]));
    println!("\nall platforms produced the SAME sequence — \"no code modification whatever\".");

    // Gaussians through the same entry point.
    let queue = Queue::new(PlatformId::A100, SyclRuntimeProfile::Dpcpp);
    let backend = native_backend_for(PlatformId::A100);
    let mut gen = backend.create_generator(EngineKind::Philox4x32x10, 7)?;
    let buf = Buffer::<f32>::new(n);
    generate_buffer(&queue, &mut gen, Distribution::gaussian(10.0, 2.0), n, &buf)?;
    let out = queue.host_read(&buf);
    let mean = out.iter().sum::<f32>() / n as f32;
    println!("gaussian(10, 2): mean of {n} samples = {mean:.3}");
    Ok(())
}
