//! END-TO-END driver (DESIGN.md "E2E"): the full three-layer stack on a
//! real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example fastcalosim_e2e
//! ```
//!
//! 1. loads the AOT-compiled Pallas artifacts (L1/L2) through PJRT,
//! 2. verifies the device RNG stream bit-matches the Rust engines,
//! 3. runs the FastCaloSim hit-deposit artifact per event — REAL compute
//!    on the request path, Python nowhere in sight,
//! 4. runs the paper's two workloads across the platform fleet (virtual
//!    clock) and reports the Fig. 5 rows + the headline VAVS numbers.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::sync::Arc;

use portarng::fastcalosim::{run_fastcalosim, FcsApi, Workload};
use portarng::metrics::vavs_efficiency;
use portarng::platform::PlatformId;
use portarng::rng::{Engine, PhiloxEngine};
use portarng::runtime::PjrtRuntime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let t0 = std::time::Instant::now();
    println!("== fastcalosim e2e: three-layer stack ==\n");

    // --- Layer 1/2: load + verify the compiled Pallas kernels. ---------
    // Offline builds gate the PJRT client (see src/xla.rs): skip the
    // device layers and still run the fleet-wide virtual comparison.
    match PjrtRuntime::discover() {
        Err(e) => {
            println!("[1-2] skipped (PJRT/artifacts unavailable): {e}\n");
        }
        Ok(rt) => {
            let rt = Arc::new(rt);
            rt.warmup(Some(&["burner_uniform_65536", "calosim_hits_16384"]))?;
            let out = rt.run_burner("burner_uniform_65536", [2024, 0], [0, 0], 0.0, 1.0)?;
            let mut want = vec![0f32; 65536];
            PhiloxEngine::new(2024).fill_uniform_f32(&mut want);
            assert_eq!(out, want, "device stream != host stream");
            println!("[1] PJRT Philox kernel bit-exact vs Rust engine (65536 draws)");

            // --- Real device compute per event: the calosim artifact. ---
            let n_events = 25;
            let mut total_dep = 0f64;
            let mut block_off = 0u64;
            let exec_t0 = std::time::Instant::now();
            for ev in 0..n_events {
                let (deposits, total) = rt.run_calosim(
                    "calosim_hits_16384",
                    [2024, ev],
                    [block_off as u32, (block_off >> 32) as u32],
                    [0.22, 1.02, 65.0 / 16384.0, 0.05, 0.05],
                )?;
                let dep_sum: f64 = deposits.iter().map(|&x| x as f64).sum();
                assert!((dep_sum - f64::from(total)).abs() / f64::from(total) < 1e-3);
                total_dep += total as f64;
                block_off += (3 * 16384) / 4;
            }
            let exec_ms = exec_t0.elapsed().as_secs_f64() * 1e3;
            println!(
                "[2] {n_events} events of 16384 hits each simulated ON DEVICE: \
                 {:.1} GeV total, {:.2} ms/event real wall ({:.1} Mhit/s)",
                total_dep,
                exec_ms / n_events as f64,
                n_events as f64 * 16384.0 / exec_ms / 1e3
            );
        }
    }

    // --- The paper's Fig. 5 across the fleet (virtual clock). -----------
    println!("\n[3] Fig. 5 rows (virtual platform clock, small workloads):");
    println!("    {:<12} {:<10} {:>14} {:>14}", "platform", "api", "single-e ms/ev", "ttbar ms/ev");
    let mut rows = Vec::new();
    for p in [PlatformId::Rome7742, PlatformId::CoreI7_10875H, PlatformId::Vega56, PlatformId::A100] {
        for api in [FcsApi::Native, FcsApi::Sycl] {
            if api == FcsApi::Native && p == PlatformId::Vega56 {
                continue; // no native HIP port (paper §7)
            }
            let se = run_fastcalosim(p, api, Workload::SingleElectron { events: 50 }, 1)?;
            let tt = run_fastcalosim(p, api, Workload::TTbar { events: 10 }, 1)?;
            println!(
                "    {:<12} {:<10} {:>14.3} {:>14.3}",
                p.token(),
                api.token(),
                se.mean_event_ms(),
                tt.mean_event_ms()
            );
            rows.push((p, api, se.mean_event_ms(), tt.mean_event_ms()));
        }
    }

    // --- Headline: near-native (VAVS ~ 1). -------------------------------
    let nat = rows.iter().find(|r| r.0 == PlatformId::A100 && r.1 == FcsApi::Native).unwrap();
    let syc = rows.iter().find(|r| r.0 == PlatformId::A100 && r.1 == FcsApi::Sycl).unwrap();
    let eff_se = vavs_efficiency(nat.2, syc.2);
    let eff_tt = vavs_efficiency(nat.3, syc.3);
    println!(
        "\n[4] headline — A100 VAVS efficiency: single-e {eff_se:.3}, ttbar {eff_tt:.3} \
         (paper: \"at par with native\")"
    );
    assert!((0.7..1.4).contains(&eff_se) && (0.7..1.4).contains(&eff_tt));

    println!("\ne2e OK in {:.1} s", t0.elapsed().as_secs_f64());
    Ok(())
}
