//! Performance-portability report: regenerates Table 2 and prints the
//! VAVS efficiency per platform/API plus the combined Pennycook P̄.
//!
//! ```bash
//! cargo run --release --example portability_report [--full]
//! ```

use portarng::repro::{table2, ExperimentId};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let quick = !std::env::args().any(|a| a == "--full");
    if quick {
        println!("(quick mode: 10 iterations/point; pass --full for the paper's 100)\n");
    }
    for t in table2(quick)? {
        println!("{}", t.to_markdown());
    }
    println!("paper's Table 2 for comparison:");
    println!("| H | P_buffer | P_usm | P_mean |");
    println!("|---|---|---|---|");
    println!("| {{Vega 56, A100}} | 1.070 | 0.393 | 0.575 |");
    println!("| {{Vega 56}} | 0.974 | 1.076 | 1.022 |");
    println!("| {{A100}} | 1.186 | 0.240 | 0.400 |");

    println!("\nall experiment ids: {:?}", ExperimentId::ALL);
    Ok(())
}
