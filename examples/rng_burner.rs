//! The paper's §5.1 RNG-burner as a standalone example: one binary, every
//! platform/API, with the real-compute PJRT path included.
//!
//! ```bash
//! cargo run --release --example rng_burner [batch]
//! ```

use std::sync::Arc;

use portarng::burner::{run_burner_auto, run_burner_with_runtime, BurnerApi, BurnerConfig};
use portarng::platform::PlatformId;
use portarng::runtime::PjrtRuntime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let batch: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(65_536);
    println!("RNG burner, Philox4x32x10 uniforms, batch {batch}, 20 iterations\n");
    println!(
        "{:<14} {:<12} {:>12} {:>10} {:>10} {:>8}",
        "platform", "api", "mean ms", "gen ms", "d2h ms", "tpb"
    );

    for platform in [PlatformId::CoreI7_10875H, PlatformId::Uhd630, PlatformId::Vega56, PlatformId::A100] {
        for api in [BurnerApi::Native, BurnerApi::SyclBuffer, BurnerApi::SyclUsm] {
            let mut cfg = BurnerConfig::paper_default(platform, api, batch);
            cfg.iterations = 20;
            let r = run_burner_auto(&cfg)?;
            println!(
                "{:<14} {:<12} {:>12.4} {:>10.4} {:>10.4} {:>8}",
                platform.token(),
                api.token(),
                r.mean_total_ns() / 1e6,
                r.breakdown.generate_ns as f64 / 1e6,
                r.breakdown.d2h_ns as f64 / 1e6,
                r.breakdown.tpb
            );
        }
    }

    // The real-compute path: the AOT Pallas kernel through PJRT.
    if let Ok(rt) = PjrtRuntime::discover() {
        let rt = Arc::new(rt);
        let mut cfg = BurnerConfig::paper_default(PlatformId::A100, BurnerApi::Pjrt, batch.min(1 << 20));
        cfg.iterations = 5;
        let r = run_burner_with_runtime(&cfg, Some(rt))?;
        println!(
            "{:<14} {:<12} {:>12.4}   (real Pallas kernel; wall {:.1} ms, sample {:?})",
            "a100",
            "pjrt",
            r.mean_total_ns() / 1e6,
            r.wall_ns as f64 / 1e6,
            &r.sample[..3.min(r.sample.len())]
        );
    } else {
        println!("(run `make artifacts` to enable the pjrt real-compute row)");
    }
    Ok(())
}
