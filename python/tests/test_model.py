"""Layer-2 correctness: model graphs (burner, calosim) shapes and physics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def u32(*xs):
    return jnp.array(xs, jnp.uint32)


def f32(*xs):
    return jnp.array(xs, jnp.float32)


def test_burner_fused_vs_two_kernel():
    n = 65536
    fused = model.burner_uniform(n)(u32(7, 8), u32(0, 0), f32(-1.0, 1.0))[0]
    twok = model.burner_uniform_two_kernel(n)(u32(7, 8), u32(0, 0), f32(-1.0, 1.0))[0]
    got, want = np.asarray(fused), np.asarray(twok)
    ulp = np.spacing(np.abs(want).astype(np.float32))
    assert np.all(np.abs(got - want) <= ulp)


def test_burner_matches_oracle():
    n = 4096
    out = model.burner_uniform(n)(u32(1, 2), u32(5, 0), f32(0.0, 1.0))[0]
    want = ref.u32_to_uniform(ref.philox_u32(n, 1, 2, off_lo=5))
    assert bool(jnp.all(out == want))


def test_gaussian_burner_moments():
    n = 65536
    out = model.burner_gaussian(n)(u32(3, 1), u32(0, 0), f32(2.0, 3.0))[0]
    assert abs(float(out.mean()) - 2.0) < 0.05
    assert abs(float(out.std()) - 3.0) < 0.05


def test_calosim_energy_conservation():
    n_hits = 16384
    dep, tot = model.calosim_hits(n_hits)(
        u32(11, 13), u32(0, 0), f32(0.5, 1.0, 0.004, 0.05, 0.05)
    )
    assert dep.shape == (ref.CALO_NCELLS,)
    # Everything lands in-grid (clipped), so deposits sum to total energy.
    np.testing.assert_allclose(float(dep.sum()), float(tot), rtol=1e-3)
    # ~65 GeV electron: e_scale = 65/16384 GeV/hit -> total ~ 65.
    dep2, tot2 = model.calosim_hits(n_hits)(
        u32(11, 13), u32(0, 0), f32(0.5, 1.0, 65.0 / n_hits, 0.05, 0.05)
    )
    assert 55.0 < float(tot2) < 75.0


def test_calosim_locality():
    """Deposits concentrate around the shower centre."""
    n_hits = 16384
    dep, _ = model.calosim_hits(n_hits)(
        u32(1, 1), u32(0, 0), f32(0.5, 1.0, 1.0, 0.05, 0.05)
    )
    dep = np.asarray(dep).reshape(ref.CALO_NETA, ref.CALO_NPHI)
    ieta = int((0.5 - ref.CALO_ETA_MIN) / ((ref.CALO_ETA_MAX - ref.CALO_ETA_MIN) / ref.CALO_NETA))
    iphi = int((1.0 - ref.CALO_PHI_MIN) / ((ref.CALO_PHI_MAX - ref.CALO_PHI_MIN) / ref.CALO_NPHI))
    win = dep[ieta - 10 : ieta + 11, iphi - 10 : iphi + 11]
    assert win.sum() > 0.95 * dep.sum()


def test_calosim_matches_ref_oracle():
    n_hits = 16384
    dep, tot = model.calosim_hits(n_hits)(
        u32(11, 13), u32(0, 0), f32(0.5, 1.0, 0.004, 0.05, 0.05)
    )
    rdep, rtot = jax.jit(
        lambda: ref.calosim_deposits(n_hits, 11, 13, 0.5, 1.0, 0.004)
    )()
    np.testing.assert_allclose(np.asarray(dep), np.asarray(rdep), atol=1e-4)
    np.testing.assert_allclose(float(tot), float(rtot), rtol=1e-5)


def test_artifact_registry_signatures():
    for name, (fn, specs) in model.ARTIFACTS.items():
        assert len(specs) == 3, name
        assert specs[0].dtype == jnp.uint32 and specs[0].shape == (2,)
        assert specs[1].dtype == jnp.uint32 and specs[1].shape == (2,)


@pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
def test_artifacts_lower(name):
    fn, specs = model.ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*specs)
    text = lowered.compiler_ir("stablehlo")
    assert "func.func public @main" in str(text)
