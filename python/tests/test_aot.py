"""AOT pipeline: HLO-text emission, manifest consistency, determinism."""

import json
import os

import jax
import pytest

from compile import aot, model

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_entry():
    fn, specs = model.ARTIFACTS["burner_uniform_4096"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: root is a tuple (Rust unwraps with to_tuple).
    assert "f32[4096]" in text


def test_lowering_deterministic():
    fn, specs = model.ARTIFACTS["burner_uniform_4096"]
    a = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    b = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert a == b


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="run `make artifacts` first",
)
def test_manifest_matches_registry():
    with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text-v1"
    assert set(manifest["artifacts"]) == set(model.ARTIFACTS)
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(ARTIFACT_DIR, entry["file"])
        assert os.path.exists(path), path
        _, specs = model.ARTIFACTS[name]
        assert len(entry["inputs"]) == len(specs)
        for got, want in zip(entry["inputs"], specs):
            assert got["dtype"] == want.dtype.name
            assert tuple(got["shape"]) == want.shape
        assert len(entry["outputs"]) >= 1
