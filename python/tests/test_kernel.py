"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer (DESIGN.md §4):
* Philox4x32x10 known-answer tests against the Random123 vectors,
* bit-exactness of the Pallas kernel against the oracle at the u01 level,
* <=1-ulp agreement on range-transformed output (XLA may contract the
  ``a + u*(b-a)`` into an FMA under jit; the eager oracle does not),
* hypothesis sweeps over seeds, offsets, ranges and sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import philox, range_transform as rt, ref

U32 = st.integers(min_value=0, max_value=0xFFFFFFFF)


def arr_u32(*xs):
    return jnp.array(xs, jnp.uint32)


def arr_f32(*xs):
    return jnp.array(xs, jnp.float32)


# ---------------------------------------------------------------------------
# Known-answer tests (Random123 kat_vectors, philox4x32x10).
# ---------------------------------------------------------------------------

KAT = [
    ((0, 0, 0, 0), (0, 0), (0x6627E8D5, 0xE169C58D, 0xBC57AC4C, 0x9B00DBD8)),
    (
        (0xFFFFFFFF,) * 4,
        (0xFFFFFFFF,) * 2,
        (0x408F276D, 0x41C83B0E, 0xA20BC7C6, 0x6D5451FD),
    ),
    (
        (0x243F6A88, 0x85A308D3, 0x13198A2E, 0x03707344),
        (0xA4093822, 0x299F31D0),
        (0xD16CFE09, 0x94FDCCEB, 0x5001E420, 0x24126EA1),
    ),
]


@pytest.mark.parametrize("ctr,key,want", KAT)
def test_philox_kat(ctr, key, want):
    got = ref.philox4x32_10(
        *(jnp.array([c], jnp.uint32) for c in ctr), key[0], key[1]
    )
    assert tuple(int(g[0]) for g in got) == want


def test_philox_counter_layout():
    """philox_u32 consumes counters (off+j, carry, 0, 0) in block order."""
    out = ref.philox_u32(8, 7, 9, off_lo=5, off_hi=0)
    b0 = ref.philox4x32_10(*(jnp.array([v], jnp.uint32) for v in (5, 0, 0, 0)), 7, 9)
    b1 = ref.philox4x32_10(*(jnp.array([v], jnp.uint32) for v in (6, 0, 0, 0)), 7, 9)
    want = [int(x[0]) for x in b0] + [int(x[0]) for x in b1]
    assert [int(x) for x in out] == want


def test_philox_offset_carry():
    """Counter low-word overflow carries into the high word."""
    out_a = ref.philox_u32(8, 1, 2, off_lo=0xFFFFFFFF, off_hi=3)
    # Second block is counter (0, 4): offset wrapped, carry applied.
    b1 = ref.philox4x32_10(*(jnp.array([v], jnp.uint32) for v in (0, 4, 0, 0)), 1, 2)
    assert [int(x) for x in out_a[4:]] == [int(x[0]) for x in b1]


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle.
# ---------------------------------------------------------------------------


def test_pallas_u01_bit_exact():
    n = 3 * 4096
    got = philox.philox_uniform(
        n, arr_u32(1234, 5678), arr_u32(0, 0), arr_f32(0.0, 1.0)
    )
    want = ref.u32_to_uniform(ref.philox_u32(n, 1234, 5678))
    assert bool(jnp.all(got == want))
    assert float(got.min()) >= 0.0 and float(got.max()) < 1.0


def test_pallas_range_one_ulp():
    n = 4096
    got = np.asarray(
        philox.philox_uniform(n, arr_u32(9, 9), arr_u32(0, 0), arr_f32(-2.0, 3.0))
    )
    want = np.asarray(ref.philox_uniform(n, 9, 9, -2.0, 3.0))
    # FMA contraction error is bounded by one ulp at the magnitude of the
    # result range endpoints, not of each (possibly near-zero) element.
    tol = np.spacing(np.float32(3.0))
    assert np.all(np.abs(got - want) <= tol)


def test_pallas_matches_jitted_oracle_bit_exact():
    n = 4096
    got = philox.philox_uniform(
        n, arr_u32(9, 9), arr_u32(0, 0), arr_f32(-2.0, 3.0)
    )
    want = jax.jit(lambda: ref.philox_uniform(n, 9, 9, -2.0, 3.0))()
    assert bool(jnp.all(got == want))


def test_pallas_gaussian_close():
    n = 65536
    got = philox.philox_gaussian(
        n, arr_u32(42, 0), arr_u32(0, 0), arr_f32(1.5, 0.5)
    )
    want = ref.philox_gaussian(n, 42, 0, 1.5, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    assert abs(float(got.mean()) - 1.5) < 0.02
    assert abs(float(got.std()) - 0.5) < 0.02


def test_standalone_transform_kernel():
    n = 4096
    u = ref.u32_to_uniform(ref.philox_u32(n, 3, 4))
    got = rt.range_transform(n, arr_f32(10.0, 20.0), u)
    want = jax.jit(lambda u: ref.range_transform(u, 10.0, 20.0))(u)
    assert bool(jnp.all(got == want))


def test_block_size_invariance():
    """Output must not depend on the BLOCK tiling, only on the counter space."""
    n = 2 * 4096
    a = philox.philox_uniform(n, arr_u32(1, 2), arr_u32(0, 0), arr_f32(0.0, 1.0))
    # Same sequence reconstructed from two offset halves.
    h0 = philox.philox_uniform(
        n // 2, arr_u32(1, 2), arr_u32(0, 0), arr_f32(0.0, 1.0)
    )
    h1 = philox.philox_uniform(
        n // 2, arr_u32(1, 2), arr_u32(n // 8, 0), arr_f32(0.0, 1.0)
    )
    assert bool(jnp.all(a == jnp.concatenate([h0, h1])))


# ---------------------------------------------------------------------------
# Hypothesis sweeps.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(key0=U32, key1=U32, off_lo=U32, off_hi=U32)
def test_hyp_pallas_u01_any_seed_offset(key0, key1, off_lo, off_hi):
    n = 4096
    got = philox.philox_uniform(
        n, arr_u32(key0, key1), arr_u32(off_lo, off_hi), arr_f32(0.0, 1.0)
    )
    want = ref.u32_to_uniform(ref.philox_u32(n, key0, key1, off_lo, off_hi))
    assert bool(jnp.all(got == want))


@settings(max_examples=25, deadline=None)
@given(
    a=st.floats(-1e6, 1e6).map(np.float32),
    w=st.floats(0.001, 1e6).map(np.float32),
    key0=U32,
)
def test_hyp_range_bounds(a, w, key0):
    n = 4096
    b = np.float32(a) + np.float32(w)
    got = philox.philox_uniform(
        n, arr_u32(key0, 1), arr_u32(0, 0), arr_f32(a, b)
    )
    tol = max(1e-2, 4.0 * float(np.spacing(max(abs(np.float32(a)), abs(b)))))
    assert float(got.min()) >= min(a, float(b)) - tol
    assert float(got.max()) <= max(a, float(b)) + tol


@settings(max_examples=10, deadline=None)
@given(key0=U32, key1=U32)
def test_hyp_uniformity_moments(key0, key1):
    n = 65536
    u = np.asarray(ref.philox_uniform(n, key0, key1))
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.var() - 1.0 / 12.0) < 0.005


@settings(max_examples=10, deadline=None)
@given(key0=U32)
def test_hyp_disjoint_offsets_disjoint_streams(key0):
    """Non-overlapping counter windows give different sequences."""
    n = 4096
    a = ref.philox_u32(n, key0, 0, off_lo=0)
    b = ref.philox_u32(n, key0, 0, off_lo=n // 4)
    assert not bool(jnp.all(a == b))


def test_mulhilo_limbs_vs_64bit():
    rng = np.random.default_rng(0)
    b = jnp.array(rng.integers(0, 2**32, size=1024, dtype=np.uint32))
    for a in (ref.PHILOX_M0, ref.PHILOX_M1, np.uint32(0xFFFFFFFF), np.uint32(1)):
        hi, lo = ref.mulhilo32(a, b)
        full = np.uint64(a) * np.asarray(b, np.uint64)
        np.testing.assert_array_equal(np.asarray(hi), (full >> 32).astype(np.uint32))
        np.testing.assert_array_equal(np.asarray(lo), (full & 0xFFFFFFFF).astype(np.uint32))
