"""Layer-2 JAX compute graphs, AOT-lowered once and executed from Rust.

Two families (DESIGN.md §3):

* **burner** — the paper's RNG-burner benchmark body: generate ``n``
  Philox4x32x10 FP32 numbers and range-transform them. The production
  variant is the single fused Pallas kernel; the ``two_kernel`` variant
  keeps generation and transform as separate kernels, mirroring the paper's
  cuRAND-call + SYCL-transform structure (used by the Fig. 4 breakdown and
  the fusion ablation).
* **calosim** — the FastCaloSim hit-deposit graph: 3 uniforms/hit -> hit
  energy + lateral position -> scatter-add into the 190k-cell grid.

All public entry points take only JAX arrays (no Python scalars) so the
lowered HLO has a stable parameter signature for the Rust runtime:
``key: u32[2], off: u32[2]`` plus per-graph f32 parameter vectors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import philox, range_transform as rt_kernel, ref


def burner_uniform(n: int):
    """Fused burner: (key, off, ab) -> f32[n] uniforms in [ab0, ab1)."""

    def fn(key, off, ab):
        return (philox.philox_uniform(n, key, off, ab),)

    return fn


def burner_uniform_two_kernel(n: int):
    """Paper-structured burner: generate-[0,1) kernel then transform kernel."""

    def fn(key, off, ab):
        u01 = jnp.array([0.0, 1.0], jnp.float32)
        u = philox.philox_uniform(n, key, off, u01)
        return (rt_kernel.range_transform(n, ab, u),)

    return fn


def burner_gaussian(n: int):
    """Fused gaussian burner: (key, off, ms) -> f32[n] ~ N(ms0, ms1)."""

    def fn(key, off, ms):
        return (philox.philox_gaussian(n, key, off, ms),)

    return fn


def calosim_hits(n_hits: int):
    """FastCaloSim hit deposits: (key, off, params) -> (deposits, total).

    ``params = [center_eta, center_phi, e_scale, sigma_eta, sigma_phi]``.
    Uniform consumption is 3 per hit, padded to the Pallas block multiple;
    the deposit math (exponential energies, lateral spread, cell binning,
    scatter-add over the 190k-cell grid) runs as plain XLA HLO fused around
    the kernel.
    """
    n_u = 3 * n_hits
    assert n_u % (4 * philox.BLOCK) == 0, (
        f"3*n_hits must be a multiple of {4 * philox.BLOCK}")

    def fn(key, off, params):
        u01 = jnp.array([0.0, 1.0], jnp.float32)
        u = philox.philox_uniform(n_u, key, off, u01).reshape(n_hits, 3)
        e = params[2] * (-jnp.log1p(-u[:, 0]))
        eta = params[0] + params[3] * (2.0 * u[:, 1] - 1.0)
        phi = params[1] + params[4] * (2.0 * u[:, 2] - 1.0)
        deta = (ref.CALO_ETA_MAX - ref.CALO_ETA_MIN) / ref.CALO_NETA
        dphi = (ref.CALO_PHI_MAX - ref.CALO_PHI_MIN) / ref.CALO_NPHI
        ieta = jnp.clip(jnp.floor((eta - ref.CALO_ETA_MIN) / deta),
                        0, ref.CALO_NETA - 1)
        iphi = jnp.clip(jnp.floor((phi - ref.CALO_PHI_MIN) / dphi),
                        0, ref.CALO_NPHI - 1)
        idx = (ieta * ref.CALO_NPHI + iphi).astype(jnp.int32)
        deposits = jnp.zeros((ref.CALO_NCELLS,), jnp.float32).at[idx].add(e)
        return (deposits, jnp.sum(e))

    return fn


# Artifact registry: name -> (builder, n, example-arg shapes).
# Rust's runtime::ArtifactRegistry mirrors this table via manifest.json.
U32_2 = jax.ShapeDtypeStruct((2,), jnp.uint32)
F32_2 = jax.ShapeDtypeStruct((2,), jnp.float32)
F32_5 = jax.ShapeDtypeStruct((5,), jnp.float32)

ARTIFACTS = {
    "burner_uniform_4096": (burner_uniform(4096), (U32_2, U32_2, F32_2)),
    "burner_uniform_65536": (burner_uniform(65536), (U32_2, U32_2, F32_2)),
    "burner_uniform_1048576": (burner_uniform(1048576), (U32_2, U32_2, F32_2)),
    "burner_uniform_2k_65536": (
        burner_uniform_two_kernel(65536), (U32_2, U32_2, F32_2)),
    "burner_gaussian_65536": (burner_gaussian(65536), (U32_2, U32_2, F32_2)),
    "calosim_hits_16384": (calosim_hits(16384), (U32_2, U32_2, F32_5)),
}
