"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts for the Rust side.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --outdir ../artifacts

Emits one ``<name>.hlo.txt`` per entry in ``model.ARTIFACTS`` plus a
``manifest.json`` describing each artifact's parameter/result signature,
consumed by ``rust/src/runtime/artifact.rs``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(s) -> dict:
    return {"dtype": s.dtype.name, "shape": list(s.shape)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact names (default: all)")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text-v1", "artifacts": {}}
    for name, (fn, arg_specs) in model.ARTIFACTS.items():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_specs = [spec_json(s) for s in
                     jax.tree_util.tree_leaves(lowered.out_info)]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [spec_json(s) for s in arg_specs],
            "outputs": out_specs,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {path} ({len(text)} chars)")

    man_path = os.path.join(args.outdir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()
