"""Layer-1 Pallas kernel: fused Philox4x32x10 generate + u01 + range transform.

TPU adaptation of the paper's cuRAND/hipRAND generation path (DESIGN.md
§Hardware-Adaptation): the counter space is tiled over a 1-D grid; each
program instance owns ``BLOCK`` 128-bit counters in VMEM and produces
``4*BLOCK`` f32 outputs.  The generate, u32->[0,1) conversion and range
transformation steps — three separate kernels in the paper (seed, generate,
transform) — are fused into a single pass so HBM traffic is exactly
4 B/number written and ~0 read (counters are synthesized in-register).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU numbers are estimated analytically in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Counters per program instance. 1024 lanes x 4 words x 4 B = 16 KiB of
# counter state + 16 KiB of output per block: far under the ~16 MiB VMEM
# budget, wide enough to keep the VPU's 8x128 lanes full.
BLOCK = 1024


def _philox_block(j, key0, key1, off_lo, off_hi):
    """Philox outputs for counter indices ``j`` (u32 vector) as (N,4) u32."""
    lo = off_lo + j
    carry = (lo < off_lo).astype(jnp.uint32)
    hi = off_hi + carry
    zero = jnp.zeros_like(lo)
    r0, r1, r2, r3 = ref.philox4x32_10(lo, hi, zero, zero, key0, key1)
    return jnp.stack([r0, r1, r2, r3], axis=-1)


def _uniform_kernel(key_ref, off_ref, ab_ref, out_ref):
    """grid=(n/(4*BLOCK),): out[i*4B:(i+1)*4B] = a + u01(philox(ctr)) * (b-a)."""
    i = pl.program_id(0)
    j = (jnp.uint32(i) * jnp.uint32(BLOCK)
         + jnp.arange(BLOCK, dtype=jnp.uint32))
    x = _philox_block(j, key_ref[0], key_ref[1], off_ref[0], off_ref[1])
    u = (x >> ref.U01_SHIFT).astype(jnp.float32) * ref.U01_SCALE
    a, b = ab_ref[0], ab_ref[1]
    out_ref[...] = (a + u * (b - a)).reshape(-1)


def _gaussian_kernel(key_ref, off_ref, ms_ref, out_ref):
    """Fused Philox + Box-Muller: out ~ N(mean, stddev)."""
    i = pl.program_id(0)
    j = (jnp.uint32(i) * jnp.uint32(BLOCK)
         + jnp.arange(BLOCK, dtype=jnp.uint32))
    x = _philox_block(j, key_ref[0], key_ref[1], off_ref[0], off_ref[1])
    u = ((x >> ref.U01_SHIFT).astype(jnp.float32) * ref.U01_SCALE).reshape(-1)
    z = ref.box_muller(u)
    out_ref[...] = ms_ref[0] + ms_ref[1] * z


def _scalar_spec():
    # Whole (tiny) scalar-argument arrays visible to every program instance.
    return pl.BlockSpec((2,), lambda i: (0,))


@functools.partial(jax.jit, static_argnums=0)
def philox_uniform(n: int, key, off, ab):
    """``n`` uniforms in [ab[0], ab[1]) — Pallas path.

    Args:
      n: static output count, multiple of ``4*BLOCK``.
      key: u32[2] generator seed words.
      off: u32[2] counter offset (lo, hi) — skip-ahead support.
      ab: f32[2] output range.
    """
    assert n % (4 * BLOCK) == 0, f"n must be a multiple of {4 * BLOCK}"
    grid = n // (4 * BLOCK)
    return pl.pallas_call(
        _uniform_kernel,
        grid=(grid,),
        in_specs=[_scalar_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=pl.BlockSpec((4 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(key.astype(jnp.uint32), off.astype(jnp.uint32), ab.astype(jnp.float32))


@functools.partial(jax.jit, static_argnums=0)
def philox_gaussian(n: int, key, off, mean_std):
    """``n`` N(mean, stddev) samples — fused Pallas Philox+Box-Muller path."""
    assert n % (4 * BLOCK) == 0, f"n must be a multiple of {4 * BLOCK}"
    grid = n // (4 * BLOCK)
    return pl.pallas_call(
        _gaussian_kernel,
        grid=(grid,),
        in_specs=[_scalar_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=pl.BlockSpec((4 * BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(key.astype(jnp.uint32), off.astype(jnp.uint32),
      mean_std.astype(jnp.float32))
