"""Layer-1 Pallas kernel: standalone range-transformation.

This mirrors the paper's Listing 1.2 — the range-transform kernel the authors
had to write in SYCL because cuRAND/hipRAND have no concept of an output
range.  The *fused* path in ``philox.py`` is what production uses; this
standalone kernel exists (a) for parity with the paper's two-kernel
structure, so the Fig. 4 per-kernel breakdown has a real artifact behind
each bar, and (b) to post-process sequences produced by other engines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _transform_kernel(ab_ref, u_ref, out_ref):
    a, b = ab_ref[0], ab_ref[1]
    out_ref[...] = a + u_ref[...] * (b - a)


@functools.partial(jax.jit, static_argnums=0)
def range_transform(n: int, ab, u):
    """out[i] = ab[0] + u[i] * (ab[1] - ab[0]); n a multiple of BLOCK."""
    assert n % BLOCK == 0, f"n must be a multiple of {BLOCK}"
    return pl.pallas_call(
        _transform_kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(ab.astype(jnp.float32), u.astype(jnp.float32))
