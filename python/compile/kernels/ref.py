"""Pure-jnp correctness oracle for the Philox4x32x10 RNG stack.

This module is the single source of truth for the numerics contract shared by
all three layers (see DESIGN.md §4):

* Philox4x32x10, Random123/cuRAND convention: 10 rounds, multipliers
  ``M = (0xD2511F53, 0xCD9E8D57)``, Weyl constants
  ``W = (0x9E3779B9, 0xBB67AE85)``, key bumped *between* rounds.
* u32 -> f32 uniform in ``[0, 1)`` via ``(x >> 8) * 2**-24``.
* Range transform ``a + u * (b - a)`` (the paper's extra kernel; cuRAND and
  hipRAND have no range concept).
* Box-Muller for gaussians, consuming uniform pairs.

Everything is written with 32-bit integer arithmetic only (16-bit limb
decomposition for the 32x32->64 multiply) so the identical expression graph
is valid inside the Pallas kernels, which cannot rely on 64-bit lanes.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Philox4x32x10 constants (Random123 / cuRAND convention).
PHILOX_M0 = np.uint32(0xD2511F53)
PHILOX_M1 = np.uint32(0xCD9E8D57)
PHILOX_W0 = np.uint32(0x9E3779B9)
PHILOX_W1 = np.uint32(0xBB67AE85)
PHILOX_ROUNDS = 10

# [0,1) conversion: keep the top 24 bits -> exactly representable in f32.
U01_SHIFT = 8
U01_SCALE = np.float32(1.0 / (1 << 24))


def mulhilo32(a, b):
    """32x32 -> (hi, lo) 32-bit product using 16-bit limbs.

    ``a`` is a (numpy) uint32 scalar constant, ``b`` a uint32 array. The limb
    form is used so the same expression lowers inside Pallas kernels where
    64-bit integer lanes are unavailable on TPU.
    """
    a = jnp.uint32(a)
    b = b.astype(jnp.uint32)
    mask = jnp.uint32(0xFFFF)
    a_lo, a_hi = a & mask, a >> 16
    b_lo, b_hi = b & mask, b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    mid = (ll >> 16) + (lh & mask) + (hl & mask)
    lo = (ll & mask) | ((mid & mask) << 16)
    hi = hh + (lh >> 16) + (hl >> 16) + (mid >> 16)
    return hi, lo


def philox_round(c0, c1, c2, c3, k0, k1):
    """One Philox4x32 S-box round."""
    hi0, lo0 = mulhilo32(PHILOX_M0, c0)
    hi1, lo1 = mulhilo32(PHILOX_M1, c2)
    return (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0)


def philox4x32_10(c0, c1, c2, c3, k0, k1):
    """Full 10-round Philox4x32 keyed permutation over u32 arrays."""
    c0, c1, c2, c3 = (x.astype(jnp.uint32) for x in (c0, c1, c2, c3))
    k0 = jnp.uint32(k0) + jnp.zeros_like(c0)
    k1 = jnp.uint32(k1) + jnp.zeros_like(c0)
    for r in range(PHILOX_ROUNDS):
        if r > 0:
            k0 = k0 + jnp.uint32(PHILOX_W0)
            k1 = k1 + jnp.uint32(PHILOX_W1)
        c0, c1, c2, c3 = philox_round(c0, c1, c2, c3, k0, k1)
    return c0, c1, c2, c3


def counters_from_offset(n_blocks, off_lo, off_hi):
    """Counter tuple for ``n_blocks`` consecutive 128-bit counters.

    Canonical layout (DESIGN.md §4): block ``j`` uses the counter
    ``(lo(off + j), hi(off + j), 0, 0)`` where ``off`` is a u64 split into
    two u32 words. Uses only 32-bit ops (manual carry).
    """
    j = jnp.arange(n_blocks, dtype=jnp.uint32)
    lo = jnp.uint32(off_lo) + j
    carry = (lo < jnp.uint32(off_lo)).astype(jnp.uint32)
    hi = jnp.uint32(off_hi) + carry
    zero = jnp.zeros_like(lo)
    return lo, hi, zero, zero


def philox_u32(n, key0, key1, off_lo=0, off_hi=0):
    """``n`` raw u32 outputs (n must be a multiple of 4)."""
    assert n % 4 == 0, "philox produces 4 u32 per counter block"
    c0, c1, c2, c3 = counters_from_offset(n // 4, off_lo, off_hi)
    r0, r1, r2, r3 = philox4x32_10(c0, c1, c2, c3, key0, key1)
    return jnp.stack([r0, r1, r2, r3], axis=1).reshape(-1)


def u32_to_uniform(x):
    """u32 -> f32 in [0, 1): keep top 24 bits."""
    return (x >> U01_SHIFT).astype(jnp.float32) * U01_SCALE


def range_transform(u, a, b):
    """The paper's range-transformation kernel: [0,1) -> [a,b)."""
    a = jnp.float32(a)
    b = jnp.float32(b)
    return a + u * (b - a)


def philox_uniform(n, key0, key1, a=0.0, b=1.0, off_lo=0, off_hi=0):
    """``n`` uniform f32 in [a, b) (n multiple of 4)."""
    return range_transform(u32_to_uniform(philox_u32(n, key0, key1, off_lo, off_hi)), a, b)


def box_muller(u):
    """Box-Muller transform over an even-length uniform array.

    ``u[0::2]`` is shifted into (0,1] (log argument must be nonzero), matching
    the cuRAND convention of strictly-positive uniforms for normals.
    """
    u = u.reshape(-1, 2)
    u1 = 1.0 - u[:, 0]  # (0, 1]
    u2 = u[:, 1]
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = jnp.float32(2.0 * np.pi) * u2
    z0 = r * jnp.cos(theta)
    z1 = r * jnp.sin(theta)
    return jnp.stack([z0, z1], axis=1).reshape(-1)


def philox_gaussian(n, key0, key1, mean=0.0, stddev=1.0, off_lo=0, off_hi=0):
    """``n`` N(mean, stddev) f32 samples (n multiple of 4)."""
    u = u32_to_uniform(philox_u32(n, key0, key1, off_lo, off_hi))
    return jnp.float32(mean) + jnp.float32(stddev) * box_muller(u)


# ---------------------------------------------------------------------------
# FastCaloSim hit-deposit oracle (single-layer grid; the full multi-layer
# logic lives in the Rust substrate — see DESIGN.md S8).
# ---------------------------------------------------------------------------

CALO_NETA = 475
CALO_NPHI = 400
CALO_NCELLS = CALO_NETA * CALO_NPHI
CALO_ETA_MIN = np.float32(-2.375)
CALO_ETA_MAX = np.float32(2.375)
CALO_PHI_MIN = np.float32(-np.pi)
CALO_PHI_MAX = np.float32(np.pi)


def calosim_deposits(n_hits, key0, key1, center_eta, center_phi, e_scale,
                     sigma_eta=0.05, sigma_phi=0.05, off_lo=0, off_hi=0):
    """Energy deposits from ``n_hits`` shower hits into the 190k-cell grid.

    Per hit, three uniforms (the paper's "three uniformly-distributed
    pseudorandom numbers ... for each hit"):
      * u_e -> hit energy  ``e_scale * (-ln(1-u_e))`` (exponential),
      * u_eta, u_phi -> lateral position offsets via a triangular-ish kernel
        ``sigma * (2u - 1)`` around the shower centre.
    Returns (deposits[NCELLS], total_energy).
    """
    n_u = 4 * ((3 * n_hits + 3) // 4)
    u = philox_uniform(n_u, key0, key1, 0.0, 1.0, off_lo, off_hi)[: 3 * n_hits]
    u = u.reshape(n_hits, 3)
    e = jnp.float32(e_scale) * (-jnp.log1p(-u[:, 0]))
    eta = jnp.float32(center_eta) + jnp.float32(sigma_eta) * (2.0 * u[:, 1] - 1.0)
    phi = jnp.float32(center_phi) + jnp.float32(sigma_phi) * (2.0 * u[:, 2] - 1.0)
    deta = (CALO_ETA_MAX - CALO_ETA_MIN) / CALO_NETA
    dphi = (CALO_PHI_MAX - CALO_PHI_MIN) / CALO_NPHI
    ieta = jnp.clip(jnp.floor((eta - CALO_ETA_MIN) / deta), 0, CALO_NETA - 1)
    iphi = jnp.clip(jnp.floor((phi - CALO_PHI_MIN) / dphi), 0, CALO_NPHI - 1)
    idx = (ieta * CALO_NPHI + iphi).astype(jnp.int32)
    deposits = jnp.zeros((CALO_NCELLS,), jnp.float32).at[idx].add(e)
    return deposits, jnp.sum(e)
